"""Hillclimb profiler: re-lower one cell and print the TOP collective ops
(by wire bytes) with their HLO metadata (op_name traces back to the JAX
source), plus the biggest dots and transposes — the §Perf "profile" on a
dry-run-only setup.

    python scripts/collective_profile.py --arch whisper-base --shape train_4k \
        [--multi-pod] [--devices 512] [--top 15] [--structure dense]
"""

import argparse
import os
import re
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--structure", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--devices", type=int, default=512)
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    from repro.configs import SHAPES, get
    from repro.launch.cells import lower_cell, make_cell
    from repro.launch.mesh import make_parallel, make_production_mesh
    from repro.roofline import analyze_compiled
    from repro.roofline.analysis import _shape_bytes

    cfg = get(args.arch, args.structure)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    parallel = make_parallel(mesh, global_batch=shape.global_batch)
    cell = make_cell(cfg, shape, parallel)
    compiled = lower_cell(cell).compile()
    t = analyze_compiled(compiled)
    print(f"== {args.arch} × {args.shape} ({args.structure or 'default'}): "
          f"compute {t.t_compute*1e3:.1f}ms memory {t.t_memory*1e3:.1f}ms "
          f"collective {t.t_collective*1e3:.1f}ms → {t.dominant}")
    print(f"   breakdown: { {k: f'{v/1e6:.0f}MB' for k, v in t.coll_breakdown.items()} }")

    text = compiled.as_text()
    line_re = re.compile(
        r"=\s*(\([^)]*\)|\S+)\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\((.*)")
    meta_re = re.compile(r'op_name="([^"]*)"')
    ops = []
    for line in text.splitlines():
        m = line_re.search(line)
        if not m:
            continue
        b = _shape_bytes(m.group(1))
        meta = meta_re.search(line)
        name = meta.group(1) if meta else "?"
        ops.append((b * (2 if m.group(2) == "all-reduce" else 1),
                    m.group(2), m.group(1)[:48], name[:140]))
    ops.sort(key=lambda x: -x[0])
    print(f"\nTop {args.top} collectives (of {len(ops)}):")
    for b, kind, shp, name in ops[: args.top]:
        print(f"  {b/1e6:9.1f}MB {kind:18s} {shp:50s} {name}")


if __name__ == "__main__":
    main()
