"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from the dry-run
artifacts (single-pod + multi-pod dirs).  §Perf entries are maintained by
hand in the perf log section as hillclimb iterations land.

    PYTHONPATH=src python scripts/render_experiments.py > /tmp/tables.md
"""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

SHAPE_TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
                "decode_32k": 128, "long_500k": 1}
ARCH_ORDER = ["recurrentgemma-2b", "granite-moe-1b-a400m", "deepseek-v3-671b",
              "smollm-135m", "internlm2-1.8b", "granite-3-2b", "qwen1.5-32b",
              "mamba2-130m", "whisper-base", "llava-next-34b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def active_params(arch):
    from repro import configs
    from repro.models import build_model
    cfg = configs.ARCHS[arch]
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = expert = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        n = float(np.prod(leaf.shape))
        total += n
        keys = [getattr(p, "key", None) for p in path]
        dense_prefix = any(isinstance(k, str) and k.startswith("pre_")
                           for k in keys)
        if cfg.moe is not None and "ffn" in keys and "shared" not in keys \
                and not dense_prefix and ("wi" in keys or "wo" in keys):
            expert += n
    if cfg.moe is not None and expert:
        total = total - expert + expert * cfg.moe.top_k / cfg.moe.n_experts
    return total


def load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_bytes(b):
    if b is None:
        return "–"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def main():
    single = load("artifacts/dryrun")
    multi = load("artifacts/dryrun_mp")
    cache = {}

    print("### §Dry-run — per-cell compile results\n")
    print("| arch | shape | 16×16 (256 chips) | 2×16×16 (512 chips) | "
          "per-device arg bytes (single-pod) | collective mix |")
    print("|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            s = single.get((arch, shape))
            m = multi.get((arch, shape))
            if s is None and m is None:
                continue
            stat = lambda r: ("✓" if r and r["status"] == "ok" else
                              ("skip" if r and r["status"] == "skipped" else
                               ("✗" if r else "–")))
            arg = s.get("memory", {}).get("argument_bytes") if s and \
                s["status"] == "ok" else None
            mix = ""
            if s and s["status"] == "ok":
                bd = s["roofline"]["coll_breakdown"]
                top = sorted(bd.items(), key=lambda kv: -kv[1])[:2]
                mix = ", ".join(f"{k} {fmt_bytes(v)}" for k, v in top)
            print(f"| {arch} | {shape} | {stat(s)} | {stat(m)} "
                  f"| {fmt_bytes(arg)} | {mix} |")

    print("\n### §Roofline — single-pod (16×16, 256 chips) baseline\n")
    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
          "dominant | MODEL_FLOPs/HLO_FLOPs |")
    print("|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        if arch not in cache:
            cache[arch] = active_params(arch)
        for shape in SHAPE_ORDER:
            r = single.get((arch, shape))
            if not r or r["status"] != "ok":
                continue
            t = r["roofline"]
            tokens = SHAPE_TOKENS[shape]
            train = shape.startswith("train")
            mf = (6.0 if train else 2.0) * cache[arch] * tokens / 256
            ratio = mf / max(t["flops"], 1.0)
            print(f"| {arch} | {shape} | {t['t_compute']*1e3:.1f} "
                  f"| {t['t_memory']*1e3:.1f} | {t['t_collective']*1e3:.1f} "
                  f"| **{t['dominant']}** | {ratio:.1%} |")

    if multi:
        print("\n### §Roofline — multi-pod (2×16×16, 512 chips) baseline\n")
        print("| arch | shape | compute (ms) | memory (ms) | "
              "collective (ms) | dominant |")
        print("|---|---|---|---|---|---|")
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                r = multi.get((arch, shape))
                if not r or r["status"] != "ok":
                    continue
                t = r["roofline"]
                print(f"| {arch} | {shape} | {t['t_compute']*1e3:.1f} "
                      f"| {t['t_memory']*1e3:.1f} "
                      f"| {t['t_collective']*1e3:.1f} "
                      f"| **{t['dominant']}** |")

    for d, title in (("artifacts/dryrun_opt",
                      "single-pod OPTIMIZED (§Perf its. 1–6b)"),
                     ("artifacts/dryrun_opt_mp",
                      "multi-pod 2×16×16 OPTIMIZED")):
        opt = load(d)
        if not opt:
            continue
        print(f"\n### §Roofline — {title}\n")
        print("| arch | shape | compute (ms) | memory (ms) | "
              "collective (ms) | dominant | arg bytes/device |")
        print("|---|---|---|---|---|---|---|")
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                r = opt.get((arch, shape))
                if not r or r["status"] != "ok":
                    continue
                t = r["roofline"]
                arg = (r.get("memory") or {}).get("argument_bytes")
                print(f"| {arch} | {shape} | {t['t_compute']*1e3:.1f} "
                      f"| {t['t_memory']*1e3:.1f} "
                      f"| {t['t_collective']*1e3:.1f} "
                      f"| **{t['dominant']}** | {fmt_bytes(arg)} |")


if __name__ == "__main__":
    main()
