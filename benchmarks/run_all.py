"""One-shot benchmark entry point with machine-readable output.

    PYTHONPATH=src python -m benchmarks.run_all [--fast] [--full] \
        [--out BENCH_kernels.json]

Runs the kernel/serving performance suite and emits ``BENCH_kernels.json``
— the per-PR perf-trajectory record:

  * ``serving``   chunk-size sweep: prefill/decode tok/s, weight+cache MB,
                  per-step latency percentiles (p50/p90/p99)
  * ``launches``  structured-matmul launches per decode step per family and
                  weight-storage mode (float/int8/int4), grouped bundles vs
                  the per-projection loop
  * ``quant``     weight+cache HBM reduction + logit deviation per family,
                  including the W4A8 integer-activation row
  * ``timings``   per-call BLAST matmul wall time across compute modes
                  (float / W8 / W8A8 / W4 / W4A8) at decode + chunk shapes
  * ``autotune``  measured-vs-heuristic tiling choices for decode-shaped
                  BLAST calls (written through a throwaway cache)

It also emits ``BENCH_serving.json`` — the serving-side record: chunk-sweep
tok/s, self-speculative decoding acceptance rate + decode speedup vs plain
per family, structured-matmul launches per decode step, the paged-pool
multi-tenant trace (TTFT/TPOT percentiles per priority class, preemption +
prefix-hit rates, priority-vs-FIFO interactive TTFT), and the chaos report
(deterministic fault injection with recovery latency and goodput under
faults).

``--full`` additionally runs the paper-table suite (``benchmarks.run``).
The JSON schema is versioned; downstream tooling should ignore unknown
keys so fields can be added per PR without breaking the trajectory.
"""

from __future__ import annotations

import argparse
import json
import time


def _jsonable(obj):
    """Recursively coerce numpy/jax scalars so the record always dumps."""
    import numpy as np

    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if hasattr(obj, "item") and getattr(obj, "ndim", None) == 0:
        return obj.item()
    return obj


def autotune_report(quiet: bool = False, cache_path: str | None = None):
    """Tune a few decode/prefill-shaped BLAST calls and report the measured
    winners next to the VMEM-heuristic picks."""
    import tempfile

    import jax

    from repro.kernels import autotune, ops

    path = cache_path or tempfile.mktemp(suffix="_blast_tiling.json")
    autotune.enable(path)
    shapes = [
        # (T, m, n, b, r, kind, act): decode matvec, small decode batch,
        # prefill chunk — plus the W8A8/W4A8 integer-activation twins of the
        # decode-batch shape, which key separately in the version-2 cache
        (1, 256, 256, 16, 32, "float", "none"),
        (8, 256, 256, 16, 32, "float", "none"),
        (128, 256, 256, 16, 32, "float", "none"),
        (8, 512, 128, 8, 48, "float", "none"),
        (8, 256, 256, 16, 32, "int8", "int8"),
        (8, 256, 256, 16, 32, "int4", "int8"),
    ]
    rows = []
    for T, m, n, b, r, kind, act in shapes:
        fb = {"float": 4, "int8": 1, "int4": 0.5}[kind]
        heur = ops.pick_blast_blocks(T, m, n, b, r, 4, fb)
        tuned = autotune.tune_blast(T, m, n, b, r, kind=kind, act=act, reps=2)
        rows.append({"T": T, "m": m, "n": n, "b": b, "r": r,
                     "kind": kind, "act": act,
                     "heuristic": list(heur), "tuned": list(tuned),
                     "backend": jax.default_backend()})
        if not quiet:
            print(f"[autotune] T={T:4d} m={m} n={n} b={b:2d} r={r} "
                  f"{kind}/a{act}: heuristic {heur} → tuned {tuned}")
    autotune.save()
    autotune.disable()
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI-sized run")
    ap.add_argument("--full", action="store_true",
                    help="also run the paper-table suite (benchmarks.run)")
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--out-serving", default="BENCH_serving.json",
                    help="serving-focused record: tok/s, speculative "
                         "acceptance rate, launches per decode step")
    ap.add_argument("--autotune-cache", default=None,
                    help="persist the autotune section's cache here")
    args = ap.parse_args()

    from benchmarks import serving_throughput

    t0 = time.time()
    print("===== serving (chunk sweep + latency percentiles) =====")
    serving = serving_throughput.run(
        n_requests=4 if args.fast else 8,
        chunks=(1, 8) if args.fast else (1, 8, 32))
    print("===== kernel launches per decode step =====")
    launches = serving_throughput.kernel_report(
        storages=("float", "int4") if args.fast
        else ("float", "int8", "int4"))
    print("===== quantized serving memory =====")
    quant = serving_throughput.quant_report(
        modes=(("int8", "int8", "none"), ("int4", "int8", "int8"))
        if args.fast
        else (("int8", "int8", "none"), ("int4", "int8", "none"),
              ("int4", "int8", "int8")))
    print("===== integer vs float kernel timings =====")
    timings = serving_throughput.kernel_timing_report(
        reps=2 if args.fast else 5)
    print("===== self-speculative decoding (draft-verify) =====")
    speculative = serving_throughput.speculative_report(
        n_requests=2 if args.fast else 4,
        max_new=16 if args.fast else 32)
    print("===== autotune (measured vs heuristic tiling) =====")
    autotune = autotune_report(cache_path=args.autotune_cache)
    print("===== mesh sweep (1 vs 8 simulated devices) =====")
    mesh = serving_throughput.mesh_report()
    print("===== paged serving (prefix sharing + preemption SLA) =====")
    paged = serving_throughput.paged_report()
    print("===== chaos (fault injection + graceful degradation) =====")
    chaos = serving_throughput.chaos_report(
        n_requests=4 if args.fast else 6,
        max_new=12 if args.fast else 16)

    import jax
    record = {
        "version": 1,
        "generated_unix": time.time(),
        "wall_s": time.time() - t0,
        "backend": jax.default_backend(),
        "serving": serving,
        "launches": launches,
        "quant": quant,
        "timings": timings,
        "autotune": autotune,
        # per-shard launch counts + collective bytes per mesh shape
        "mesh": mesh,
    }
    with open(args.out, "w") as f:
        json.dump(_jsonable(record), f, indent=2)
    print(f"[run_all] wrote {args.out} ({time.time() - t0:.0f}s)")

    serving_record = {
        "version": 1,
        "generated_unix": time.time(),
        "backend": jax.default_backend(),
        # chunk-sweep tok/s, draft-verify acceptance + speedup, and
        # structured-matmul launches per decode step
        "serving": serving,
        "speculative": speculative,
        "launches": launches,
        # paged pool under a multi-tenant trace: TTFT/TPOT percentiles per
        # priority class, preemption + prefix-hit rates, FIFO contrast
        "paged": paged,
        # 1-device vs 8-device (simulated) mesh: tok/s per mesh shape,
        # per-shard launches per decode step, collective + replicated bytes
        "mesh": mesh,
        # fault injection on the paged+speculative engine: faults fired per
        # kind, degradation-ladder counts, per-fault recovery latency, and
        # goodput under faults vs the fault-free run (non-faulted requests
        # asserted token-identical)
        "chaos": chaos,
    }
    with open(args.out_serving, "w") as f:
        json.dump(_jsonable(serving_record), f, indent=2)
    print(f"[run_all] wrote {args.out_serving}")

    if args.full:
        import sys

        from benchmarks import run as paper_run
        sys.argv = ["benchmarks.run"] + (["--fast"] if args.fast else [])
        paper_run.main()


if __name__ == "__main__":
    main()
