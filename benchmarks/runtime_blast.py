"""Paper Table 4 (Llama-7B text-generation runtime) — adapted protocol.

The paper measures wall-clock on an A100.  Offline we measure (i) CPU
wall-time of the jitted XLA BLAST matmul vs dense at the exact Llama-7B
layer shapes (b ∈ {2,16}, CR ∈ {20%, 50%}) for matmul (prefill-like,
T=512) and matvec (decode, T=1); and (ii) the DERIVED TPU-v5e roofline
times from parameter bytes (the paper itself attributes the speedup to
reduced memory traffic in the bandwidth-bound decode regime)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blast
from repro.roofline import HW_V5E


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))  # compile + warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(quiet=False, T_prefill=256):
    shapes = [("attn_4096x4096", 4096, 4096), ("mlp_11008x4096", 4096, 11008)]
    rows = []
    for T in (1, T_prefill):
        for name, n, m in shapes:
            x = jax.random.normal(jax.random.PRNGKey(0), (T, n), jnp.float32)
            w = jax.random.normal(jax.random.PRNGKey(1), (n, m), jnp.float32)
            dense_fn = jax.jit(lambda x, w: x @ w)
            t_dense = _time(dense_fn, x, w)
            dense_bytes = n * m * 2  # bf16 weights on the wire/HBM
            rows.append({"T": T, "layer": name, "kind": "dense", "b": 0,
                         "CR": 0.0, "cpu_ms": t_dense * 1e3,
                         "v5e_mem_us": dense_bytes / HW_V5E.hbm_bw * 1e6})
            for b in (2, 16):
                for cr in (0.2, 0.5):
                    r = blast.rank_for_compression(m, n, b, 1 - cr, align=16)
                    params = blast.init(jax.random.PRNGKey(2), m, n, b, r)
                    mm = jax.jit(lambda x, U, S, V: blast.matmul(
                        x, blast.BlastParams(U, S, V)))
                    t = _time(mm, x, params.U, params.S, params.V)
                    pbytes = blast.num_params(m, n, b, r) * 2
                    rows.append({
                        "T": T, "layer": name, "kind": "blast", "b": b,
                        "CR": cr, "cpu_ms": t * 1e3,
                        "v5e_mem_us": pbytes / HW_V5E.hbm_bw * 1e6,
                        "speedup_cpu": t_dense / t,
                        "speedup_v5e_mem": dense_bytes / pbytes,
                    })
                    if not quiet:
                        print(f"[table4] T={T:4d} {name:16s} BLAST b={b:2d} "
                              f"CR={cr:.0%} r={r:5d}: cpu {t*1e3:7.2f}ms "
                              f"({t_dense/t:4.2f}× vs dense) | v5e decode "
                              f"roofline {dense_bytes/pbytes:.2f}×")
    return rows


if __name__ == "__main__":
    run()
