"""Paper §4.2 (Tables 2/3, Fig. 7) offline protocol: compression + re-training.

1. Train a small dense LM to convergence on the synthetic stream (the
   "pre-trained foundation model").
2. Compress every structured linear to each baseline at 20% / 50% CR:
   BLAST via Algorithm 2 (PrecGD), low-rank via SVD, block-diagonal via
   block extraction, Monarch via Adam fit.
3. Report task loss compression-only (paper Table 12) and after re-training
   (paper Table 3/13), plus per-weight reconstruction error.

Claims reproduced: (i) BLAST compression-only degrades far less than
Monarch/Block-Diagonal; (ii) re-training recovers most of the gap at 50%."""

import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.compress import compress_linear, reconstruction_error
from repro.core.structures import StructureConfig, make_linear
from repro.data import TokenStream
from repro.models import build_model
from repro.optim import adamw, cosine_schedule, constant_schedule
from repro.train import Trainer, make_loss_fn


class _Data:
    def __init__(self, cfg, batch=16, seq=64):
        self.stream = TokenStream(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch)

    def batch(self, step):
        return self.stream.batch(step)


def compress_model(dense_params, structured_model, kind: str,
                   keep: float, steps=120):
    """Map every 2-D dense weight onto the target structure's params.

    The dense and structured models share the exact tree topology except at
    structured-linear leaves ({"w"} vs the structure's factor dict), so a
    joint recursive walk identifies every compression site."""
    st_params = structured_model.init(jax.random.PRNGKey(1))
    errs = []

    def is_site(dp, sp):
        """Dense {"w": 2-D} leaf whose structured counterpart has different
        factor names OR a different "w" shape (block-diag keeps the name)."""
        if not (isinstance(dp, dict) and set(dp) == {"w"}
                and dp["w"].ndim == 2 and isinstance(sp, dict)):
            return False
        return set(sp) != {"w"} or sp["w"].shape != dp["w"].shape

    def fill(dp, sp):
        if isinstance(dp, dict) and isinstance(sp, dict):
            if is_site(dp, sp):
                d_in, d_out = dp["w"].shape
                spec = make_linear(
                    d_in, d_out, StructureConfig(kind=kind, b=4, keep_ratio=keep))
                out = compress_linear(dp["w"], spec, steps=steps)
                errs.append(reconstruction_error(dp["w"], spec, out))
                return {k: out[k].astype(v.dtype) for k, v in sp.items()}
            return {k: fill(dp[k], sp[k]) if k in dp else sp[k] for k in sp}
        return dp if dp is not None else sp

    return fill(dense_params, st_params), errs


def run(quiet=False, pretrain_steps=200, retrain_steps=60):
    # scan_layers=False: per-layer (2-D) weight leaves, the per-weight
    # compression walk's contract
    base = configs.ARCHS["gpt2-blast"].reduced(
        vocab=128, d_model=64, n_layers=2, d_ff=128, n_heads=4, n_kv_heads=4,
        head_dim=16, scan_layers=False)
    dense_cfg = dataclasses.replace(base, structure=StructureConfig("dense"),
                                    structure_ffn=None)
    dense_model = build_model(dense_cfg)
    data = _Data(dense_cfg)
    trainer = Trainer(dense_model, adamw(cosine_schedule(3e-3, pretrain_steps, 10)),
                      data, log_every=10_000)
    out = trainer.run(pretrain_steps)
    dense_params = out["params"]
    loss_fn = make_loss_fn(dense_model)
    base_loss = float(loss_fn(dense_params, data.batch(999))[0])
    if not quiet:
        print(f"[table3] dense pre-trained loss {base_loss:.4f}")

    rows = []
    for keep in (0.8, 0.5):
        for kind in ("blast", "low_rank", "monarch", "block_diag"):
            cfg = dataclasses.replace(
                base, structure=StructureConfig(kind=kind, b=4, keep_ratio=keep),
                structure_ffn=None)
            model = build_model(cfg)
            params, errs = compress_model(dense_params, model, kind, keep)
            lf = make_loss_fn(model)
            loss0 = float(lf(params, data.batch(999))[0])
            # re-train from the compressed initialization (paper §3.2)
            opt = adamw(constant_schedule(1e-3))
            from repro.train import make_train_step
            step = jax.jit(make_train_step(model, opt))
            p, s = params, opt.init(params)
            for i in range(retrain_steps):
                p, s, m = step(p, s, data.batch(i))
            loss1 = float(lf(p, data.batch(999))[0])
            rec = sum(errs) / len(errs)
            rows.append({"kind": kind, "CR": 1 - keep, "recon_err": rec,
                         "loss_compress_only": loss0, "loss_retrained": loss1,
                         "dense_loss": base_loss})
            if not quiet:
                print(f"[table3] CR={1-keep:.0%} {kind:10s} recon {rec:.3f} "
                      f"loss {loss0:8.3f} → retrained {loss1:8.3f} "
                      f"(dense {base_loss:.3f})")
    return rows


if __name__ == "__main__":
    run()
