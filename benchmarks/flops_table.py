"""Paper Table 1 / Figs. 4+6 FLOPs accounting: relative FLOPs of ViT-Base
and GPT-2 with each structured matrix at the paper's settings (counting
multiplications, as the paper does).

Checks BLAST₃'s published 27.8% relative-FLOPs point for ViT-Base is
reproduced by our spec arithmetic (paper r for BLAST₃ ViT solves from the
budget; here we report the curve).

Alongside FLOPs, each row reports *bytes per decoded token*: at batch 1
every linear's params are read once per token, so the decode roofline term
is exactly the storage footprint — bf16 (2 B/param) vs per-block int8
(1 B/param + scales, computed exactly from the quantized tree)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.structures import StructureConfig, make_linear


def _model_linears(cfg, structure: StructureConfig):
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    width = 2 * cfg.d_ff if cfg.ffn_kind == "swiglu" else cfg.d_ff
    return [
        make_linear(cfg.d_model, (hq + 2 * hkv) * hd, structure),
        make_linear(hq * hd, cfg.d_model, structure),
        make_linear(cfg.d_model, width, structure),
        make_linear(cfg.d_ff, cfg.d_model, structure),
    ]


def model_linear_flops(cfg, structure: StructureConfig, specs=None) -> int:
    """Per-token multiplications in the structured linears (attn qkv/out +
    ffn), matching the paper's accounting (§4: count multiplications)."""
    specs = _model_linears(cfg, structure) if specs is None else specs
    return sum(s.flops_per_token for s in specs) * cfg.n_layers


def model_linear_bytes(cfg, structure: StructureConfig,
                       specs=None) -> tuple[int, int]:
    """(bf16 bytes, int8 bytes) read per decoded token by the structured
    linears.  The int8 figure traces each spec's own ``quantize`` under
    ``jax.eval_shape`` — exact codes + per-block scale accounting from the
    abstract shapes, no array allocation or compute."""
    specs = _model_linears(cfg, structure) if specs is None else specs
    bf16 = sum(s.num_params for s in specs) * 2
    int8 = 0
    for s in specs:
        abstract = jax.eval_shape(lambda spec=s: spec.quantize(
            {k: jnp.zeros(sh, jnp.float32) for k, sh in spec.shapes.items()},
            8))
        int8 += sum(int(np.prod(l.shape)) * l.dtype.itemsize
                    for l in jax.tree.leaves(abstract))
    return bf16 * cfg.n_layers, int8 * cfg.n_layers


def run(quiet=False):
    rows = []
    for arch, b in (("vit-base-blast", 3), ("gpt2-blast", 6)):
        cfg = configs.ARCHS[arch]
        dense = model_linear_flops(cfg, StructureConfig(kind="dense"))
        for keep in (0.15, 0.3, 0.5, 0.7):
            for kind in ("blast", "low_rank", "monarch", "block_diag"):
                st = StructureConfig(kind=kind, b=b, keep_ratio=keep)
                specs = _model_linears(cfg, st)
                f = model_linear_flops(cfg, st, specs)
                b16, i8 = model_linear_bytes(cfg, st, specs)
                rows.append({"arch": arch, "kind": kind, "keep": keep,
                             "rel_flops_pct": 100.0 * f / dense,
                             "bytes_tok_bf16": b16, "bytes_tok_int8": i8})
                if not quiet:
                    print(f"[table1] {arch:16s} {kind:10s} keep={keep:.2f} "
                          f"rel FLOPs {100.0 * f / dense:6.1f}%  "
                          f"B/tok {b16 / 2**20:6.1f} MiB bf16 → "
                          f"{i8 / 2**20:6.1f} MiB int8 "
                          f"({b16 / max(i8, 1):.2f}×)")
    return rows


if __name__ == "__main__":
    run()
