"""Paper Table 1 / Figs. 4+6 FLOPs accounting: relative FLOPs of ViT-Base
and GPT-2 with each structured matrix at the paper's settings (counting
multiplications, as the paper does).

Checks BLAST₃'s published 27.8% relative-FLOPs point for ViT-Base is
reproduced by our spec arithmetic (paper r for BLAST₃ ViT solves from the
budget; here we report the curve)."""

import dataclasses

from repro import configs
from repro.core.structures import StructureConfig, make_linear


def model_linear_flops(cfg, structure: StructureConfig) -> int:
    """Per-token multiplications in the structured linears (attn qkv/out +
    ffn), matching the paper's accounting (§4: count multiplications)."""
    c = dataclasses.replace(cfg, structure=structure, structure_ffn=None)
    hq, hkv, hd = c.n_heads, c.n_kv_heads, c.head_dim_
    qkv = make_linear(c.d_model, (hq + 2 * hkv) * hd, structure)
    out = make_linear(hq * hd, c.d_model, structure)
    width = 2 * c.d_ff if c.ffn_kind == "swiglu" else c.d_ff
    wi = make_linear(c.d_model, width, structure)
    wo = make_linear(c.d_ff, c.d_model, structure)
    per_layer = (qkv.flops_per_token + out.flops_per_token
                 + wi.flops_per_token + wo.flops_per_token)
    return per_layer * c.n_layers


def run(quiet=False):
    rows = []
    for arch, b in (("vit-base-blast", 3), ("gpt2-blast", 6)):
        cfg = configs.ARCHS[arch]
        dense = model_linear_flops(cfg, StructureConfig(kind="dense"))
        for keep in (0.15, 0.3, 0.5, 0.7):
            for kind in ("blast", "low_rank", "monarch", "block_diag"):
                st = StructureConfig(kind=kind, b=b, keep_ratio=keep)
                f = model_linear_flops(cfg, st)
                rows.append({"arch": arch, "kind": kind, "keep": keep,
                             "rel_flops_pct": 100.0 * f / dense})
                if not quiet:
                    print(f"[table1] {arch:16s} {kind:10s} keep={keep:.2f} "
                          f"rel FLOPs {100.0 * f / dense:6.1f}%")
    return rows


if __name__ == "__main__":
    run()
