"""Paper Fig. 3 + App. D.1 Fig. 9: BLAST factorization convergence — GD vs
preconditioned GD (Alg. 2), exact (r = r*) and over-parameterized (r > r*),
on (a) a low-rank target and (b) a BLAST_16 target.  256×256, r* = 8.

Expected reproduction: with r = r*, both optimizers find low error on the
low-rank target; with r = 32 > r*, plain GD stalls while PrecGD still
converges (orders-of-magnitude error gap) — the paper's headline claim for
Algorithm 2."""

import jax
import jax.numpy as jnp

from repro.core import blast
from repro.core.factorize import factorize, normalized_error


def make_targets(n=256, r_star=8, b=16, key=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(key), 3)
    u = jax.random.normal(k1, (n, r_star)) / jnp.sqrt(r_star)
    v = jax.random.normal(k2, (n, r_star))
    low_rank = u @ v.T
    params = blast.init(k3, n, n, b, r_star, dtype=jnp.float32)
    blast_t = blast.to_dense(params)
    return {"low_rank": low_rank, "blast16": blast_t}


def run(steps=150, n=256, r_star=8, b=16, quiet=False):
    rows = []
    for tname, A in make_targets(n, r_star, b).items():
        for r in (r_star, 4 * r_star):
            for method, precondition in (("gd", False), ("precgd", True)):
                # GD baseline uses the Theorem-1 spectral step sizes
                # (monotone non-increase guaranteed — a fixed lr diverges)
                res = factorize(A, b, r, steps=steps,
                                precondition=precondition,
                                spectral_steps=not precondition,
                                lr=1.0)
                err = float(normalized_error(A, res.params))
                rows.append({"target": tname, "r": r, "method": method,
                             "rel_err": err})
                if not quiet:
                    print(f"[fig3] target={tname:9s} r={r:3d} {method:7s} "
                          f"rel_err={err:.3e}")
    # the paper's claim, as asserts:
    def get(t, r, m):
        return next(x["rel_err"] for x in rows
                    if x["target"] == t and x["r"] == r and x["method"] == m)
    overparam_gap = get("low_rank", 4 * r_star, "gd") / max(
        get("low_rank", 4 * r_star, "precgd"), 1e-12)
    if not quiet:
        print(f"[fig3] overparameterized GD/PrecGD error ratio (low-rank "
              f"target): {overparam_gap:.1f}× (paper: ≫1)")
    return rows


if __name__ == "__main__":
    run()
