"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

  fig3    factorization convergence (GD vs PrecGD)        paper Fig. 3 / 9
  table1  relative-FLOPs accounting per structure         paper Table 1 / Fig. 4/6
  fig5    from-scratch LM loss–FLOPs trade-off            paper Fig. 5
  table3  compression + re-training per structure          paper Tables 2/3/12/13
  table4  BLAST vs dense runtime (CPU) + v5e bytes model   paper Table 4
  roofline  dry-run roofline table (if artifacts exist)    assignment §Roofline
"""

import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller steps (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig3,table4")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (compress_retrain, factorization_convergence,
                            flops_table, from_scratch_lm, roofline_report,
                            runtime_blast, serving_throughput)

    benches = [
        ("fig3", lambda: factorization_convergence.run(
            steps=60 if args.fast else 150)),
        ("table1", flops_table.run),
        ("fig5", lambda: from_scratch_lm.run(
            steps=40 if args.fast else 150)),
        ("table3", lambda: compress_retrain.run(
            pretrain_steps=60 if args.fast else 200,
            retrain_steps=20 if args.fast else 60)),
        ("table4", lambda: runtime_blast.run(
            T_prefill=64 if args.fast else 256)),
        ("serving", lambda: serving_throughput.run(
            n_requests=6 if args.fast else 12)),
        ("roofline", roofline_report.run),
    ]
    failed = []
    for name, fn in benches:
        if only and name not in only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn()
            print(f"===== {name} done in {time.time()-t0:.0f}s =====")
        except Exception:  # keep the harness going
            import traceback
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"[benchmarks] FAILED: {failed}")
        sys.exit(1)
    print("\n[benchmarks] all passed")


if __name__ == "__main__":
    main()
