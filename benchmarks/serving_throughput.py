"""Beyond-paper serving benchmark: chunked-prefill throughput on a
prompt-heavy workload.

The paper's Table-4 scenario is batch=1 generation; production serving is
dominated by *prompt ingestion* — BLaST's block matmuls are starved at T=1
and saturated at T=chunk, so the engine's chunk size C directly sets how
many (tokens × rank) rows each structured matmul sees per step.  This sweep
serves the same prompt-heavy request mix at several chunk sizes and reports
prefill-tokens/s and decode-tokens/s separately: prefill throughput should
climb with C (ceil(L/C) steps instead of L per prompt) while decode
throughput stays flat (decode steps are C-independent).
"""

import time

import jax

from repro import configs
from repro.models import build_model
from repro.serve import Engine, Request


def _mk_requests(n, vocab, key, prompt_len=48, max_new=8):
    """Prompt-heavy mix: long prompts, short completions."""
    reqs = []
    for i in range(n):
        plen = prompt_len - 8 + (i * 5) % 17
        toks = jax.random.randint(jax.random.fold_in(key, i), (plen,), 0, vocab)
        reqs.append(Request(uid=i, prompt=[int(t) for t in toks],
                            max_new_tokens=4 + (i * 3) % max_new))
    return reqs


def run(quiet=False, n_requests=8, slots=4, chunks=(1, 8, 32)):
    cfg = configs.ARCHS["smollm-135m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    step_fn = jax.jit(model.prefill_chunk)  # shared: compiles keyed by (B, C)

    rows = []
    for chunk in chunks:
        # warm every chunk bucket the timed run can hit, outside the timed
        # region: the power-of-two ladder below chunk (prompt remainders)
        # plus a full-chunk prompt (covers _bucket(chunk) when chunk is not
        # itself a power of two)
        warm_lens = []
        c = 1
        while c < chunk:
            warm_lens.append(c)
            c *= 2
        warm_lens.append(chunk)
        for c in warm_lens:
            warm = Engine(model, params, batch_slots=slots, max_len=128,
                          chunk_size=chunk, step_fn=step_fn)
            warm.submit(Request(uid=-1, prompt=list(range(1, 1 + c)),
                                max_new_tokens=2))
            warm.run()

        eng = Engine(model, params, batch_slots=slots, max_len=128,
                     chunk_size=chunk, step_fn=step_fn)
        for r in _mk_requests(n_requests, cfg.vocab, key):
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        assert len(done) == n_requests
        tp = eng.throughput()
        rows.append({
            "chunk": chunk,
            "steps": tp["steps"],
            "prefill_tok_s": tp["prefill_tok_s"],
            "decode_tok_s": tp["decode_tok_s"],
            "wall_s": wall,
        })
        if not quiet:
            print(f"[serving] C={chunk:3d}: {tp['steps']:4d} steps, "
                  f"prefill {tp['prefill_tok_s']:8.1f} tok/s, "
                  f"decode {tp['decode_tok_s']:7.1f} tok/s, "
                  f"wall {wall:5.1f}s")
    if not quiet and len(rows) > 1:
        gain = rows[-1]["prefill_tok_s"] / max(rows[0]["prefill_tok_s"], 1e-9)
        print(f"[serving] chunked prefill C={rows[-1]['chunk']} vs "
              f"token-at-a-time: {gain:.2f}× prefill throughput "
              f"({n_requests} prompt-heavy reqs, {slots} slots)")
    return rows


if __name__ == "__main__":
    run()
