"""Beyond-paper serving benchmark: continuous batching vs drain-batching
throughput on the compressed model (the paper's Table-4 scenario is batch=1
generation; production serving is batched — this quantifies what the engine
layer adds on top of the BLAST compute savings).

Static ("drain") batching admits a full batch and waits for every request
to finish before admitting the next; continuous batching recycles slots per
token.  With mixed output lengths the drain baseline idles slots."""

import time

import jax

from repro import configs
from repro.models import build_model
from repro.serve import Engine, Request


def _mk_requests(n, vocab, key, max_new_spread=(4, 24)):
    lo, hi = max_new_spread
    reqs = []
    for i in range(n):
        plen = 3 + (i * 5) % 8
        toks = jax.random.randint(jax.random.fold_in(key, i), (plen,), 0, vocab)
        reqs.append(Request(uid=i, prompt=[int(t) for t in toks],
                            max_new_tokens=lo + (i * 7) % (hi - lo)))
    return reqs


def run(quiet=False, n_requests=12, slots=4):
    cfg = configs.ARCHS["smollm-135m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    step_fn = jax.jit(model.decode_step)

    # warm the compile outside both timed regions (shared step_fn)
    warm = Engine(model, params, batch_slots=slots, max_len=96,
                  step_fn=step_fn)
    warm.submit(Request(uid=-1, prompt=[1], max_new_tokens=1))
    warm.run()

    # continuous batching: one engine, rolling admission
    eng = Engine(model, params, batch_slots=slots, max_len=96,
                 step_fn=step_fn)
    for r in _mk_requests(n_requests, cfg.vocab, key):
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    t_cont = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)

    # drain batching: admit `slots` requests, run to completion, repeat
    reqs = _mk_requests(n_requests, cfg.vocab, key)
    t0 = time.perf_counter()
    toks_drain = 0
    for i in range(0, n_requests, slots):
        eng2 = Engine(model, params, batch_slots=slots, max_len=96,
                      step_fn=step_fn)
        for r in reqs[i: i + slots]:
            eng2.submit(r)
        toks_drain += sum(len(r.output) for r in eng2.run())
    t_drain = time.perf_counter() - t0

    row = {"continuous_tok_s": toks / t_cont,
           "drain_tok_s": toks_drain / t_drain,
           "speedup": (toks / t_cont) / (toks_drain / t_drain)}
    if not quiet:
        print(f"[serving] continuous {row['continuous_tok_s']:.1f} tok/s vs "
              f"drain {row['drain_tok_s']:.1f} tok/s → "
              f"{row['speedup']:.2f}× from slot recycling "
              f"({n_requests} reqs, {slots} slots, mixed lengths)")
    return [row]


if __name__ == "__main__":
    run()
