"""Beyond-paper serving benchmark: chunked-prefill throughput on a
prompt-heavy workload.

The paper's Table-4 scenario is batch=1 generation; production serving is
dominated by *prompt ingestion* — BLaST's block matmuls are starved at T=1
and saturated at T=chunk, so the engine's chunk size C directly sets how
many (tokens × rank) rows each structured matmul sees per step.  This sweep
serves the same prompt-heavy request mix at several chunk sizes and reports
prefill-tokens/s and decode-tokens/s separately: prefill throughput should
climb with C (ceil(L/C) steps instead of L per prompt) while decode
throughput stays flat (decode steps are C-independent).

``quant_report`` covers the memory half: for each of the four decoder
families (GQA / MLA / SSD / RG-LRU) it compares the resident weight+cache
HBM bytes of bf16 serving against quantized storage (int8 and int4-packed
weights, int8 caches) and the final-logit deviation the quantization
introduces on a smoke prompt.

``kernel_report`` covers the launch half: per decoder family it counts the
structured-matmul dispatches one decode step issues (each == one
pallas_call on the fused-kernel path) with the grouped projection bundles
enabled vs the per-projection loop, and reduces the engine's recorded
per-step wall times to latency percentiles.  Grouping must show strictly
fewer launches per decode step wherever a family has a same-input bundle
(GQA gate+up, MLA a-projections + gate+up, RG-LRU input/gate pairs).
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro import quant as qt
from repro.core import structures
from repro.models import build_model
from repro.quant import QuantConfig
from repro.serve import (Engine, EngineConfig, MemoryConfig, Request,
                        SamplingParams, SchedulerConfig, SpeculativeConfig)


def _percentiles(samples) -> dict:
    """Per-step latency percentiles (p50/p90/p99) in seconds."""
    if not samples:
        return {"p50": 0.0, "p90": 0.0, "p99": 0.0}
    arr = np.asarray(samples, np.float64)
    return {f"p{p}": float(np.percentile(arr, p)) for p in (50, 90, 99)}


def _mk_requests(n, vocab, key, prompt_len=48, max_new=8):
    """Prompt-heavy mix: long prompts, short completions."""
    reqs = []
    for i in range(n):
        plen = prompt_len - 8 + (i * 5) % 17
        toks = jax.random.randint(jax.random.fold_in(key, i), (plen,), 0, vocab)
        reqs.append(Request(uid=i, prompt=[int(t) for t in toks],
                            max_new_tokens=4 + (i * 3) % max_new))
    return reqs


def run(quiet=False, n_requests=8, slots=4, chunks=(1, 8, 32)):
    cfg = configs.ARCHS["smollm-135m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    step_fn = jax.jit(model.prefill_chunk)  # shared: compiles keyed by (B, C)

    rows = []
    for chunk in chunks:
        # warm every chunk bucket the timed run can hit, outside the timed
        # region: the power-of-two ladder below chunk (prompt remainders)
        # plus a full-chunk prompt (covers _bucket(chunk) when chunk is not
        # itself a power of two)
        warm_lens = []
        c = 1
        while c < chunk:
            warm_lens.append(c)
            c *= 2
        warm_lens.append(chunk)
        for c in warm_lens:
            warm = Engine(model, params, EngineConfig(
                scheduler=SchedulerConfig(slots=slots, chunk_size=chunk),
                memory=MemoryConfig(max_len=128)), step_fn=step_fn)
            warm.submit(Request(uid=-1, prompt=list(range(1, 1 + c)),
                                max_new_tokens=2))
            warm.run()

        eng = Engine(model, params, EngineConfig(
            scheduler=SchedulerConfig(slots=slots, chunk_size=chunk),
            memory=MemoryConfig(max_len=128)), step_fn=step_fn)
        for r in _mk_requests(n_requests, cfg.vocab, key):
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        assert len(done) == n_requests
        tp = eng.throughput()
        hbm_mb = (qt.tree_nbytes(eng.params) + qt.tree_nbytes(eng.cache)) / 2**20
        rows.append({
            "chunk": chunk,
            "steps": tp["steps"],
            "prefill_tok_s": tp["prefill_tok_s"],
            "decode_tok_s": tp["decode_tok_s"],
            "wall_s": wall,
            "weight_cache_mb": hbm_mb,
            "step_latency_s": _percentiles(eng.stats["step_s"]),
            "decode_step_latency_s": _percentiles(eng.stats["decode_step_s"]),
        })
        if not quiet:
            pct = rows[-1]["decode_step_latency_s"]
            print(f"[serving] C={chunk:3d}: {tp['steps']:4d} steps, "
                  f"prefill {tp['prefill_tok_s']:8.1f} tok/s, "
                  f"decode {tp['decode_tok_s']:7.1f} tok/s, "
                  f"wall {wall:5.1f}s, weight+cache {hbm_mb:6.2f} MB, "
                  f"decode p50/p99 {pct['p50'] * 1e3:.1f}/"
                  f"{pct['p99'] * 1e3:.1f} ms")
    if not quiet and len(rows) > 1:
        gain = rows[-1]["prefill_tok_s"] / max(rows[0]["prefill_tok_s"], 1e-9)
        print(f"[serving] chunked prefill C={rows[-1]['chunk']} vs "
              f"token-at-a-time: {gain:.2f}× prefill throughput "
              f"({n_requests} prompt-heavy reqs, {slots} slots)")
    return rows


# -- quantized-serving memory report ----------------------------------------

FAMILIES = {
    "gqa": "smollm-135m",
    "mla": "deepseek-v3-671b",
    "ssd": "mamba2-130m",
    "rglru": "recurrentgemma-2b",
}


def quant_report(quiet=False, batch=4, max_len=64, prompt_len=12,
                 modes=(("int8", "int8", "none"), ("int4", "int8", "none"),
                        ("int4", "int8", "int8"))):
    """Weight+cache HBM bytes and final-logit deviation, bf16 vs quantized.

    For each decoder family: build the reduced smoke model in bf16, then the
    same arch with ``quant=(weights, cache, activations)``; quantize the
    *same* float params, run one prefill chunk through both, and report the
    resident memory ratio plus max |Δlogit|.  int8 weights halve storage
    (minus the per-block scale overhead); int4-packed weights quarter it, so
    the combined weight+cache reduction clears 2× with margin.  The
    activations="int8" row (W4A8) adds the per-token activation-rounding
    error on top of the weight error — storage is identical to the W4 row,
    only compute changes.
    """
    rows = []
    for family, arch in FAMILIES.items():
        cfg = configs.ARCHS[arch].reduced(param_dtype="bfloat16",
                                          compute_dtype="bfloat16")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(batch, max_len)
        w_mb = qt.tree_nbytes(params) / 2**20
        c_mb = qt.tree_nbytes(cache) / 2**20
        tokens = jax.random.randint(jax.random.PRNGKey(1),
                                    (batch, prompt_len), 0, cfg.vocab)
        steps = jnp.zeros((batch,), jnp.int32)
        n_tok = jnp.full((batch,), prompt_len, jnp.int32)
        base_logits, _ = model.prefill_chunk(params, cache, tokens, steps, n_tok)
        base = np.asarray(base_logits, np.float32)
        for weights, cache_mode, act in modes:
            qcfg = QuantConfig(weights=weights, cache=cache_mode,
                               activations=act)
            cfg_q = dataclasses.replace(cfg, quant=qcfg)
            model_q = build_model(cfg_q)
            params_q = model_q.quantize_params(params, qcfg)
            cache_q = model_q.init_cache(batch, max_len)
            wq_mb = qt.tree_nbytes(params_q) / 2**20
            cq_mb = qt.tree_nbytes(cache_q) / 2**20
            with structures.activations(act):
                q_logits, _ = model_q.prefill_chunk(params_q, cache_q, tokens,
                                                    steps, n_tok)
            dev = float(np.abs(np.asarray(q_logits, np.float32) - base).max())
            rel = dev / (np.abs(base).max() + 1e-9)
            reduction = (w_mb + c_mb) / (wq_mb + cq_mb)
            rows.append({
                "family": family, "arch": arch,
                "weights": weights, "cache": cache_mode, "activations": act,
                "bf16_mb": w_mb + c_mb, "quant_mb": wq_mb + cq_mb,
                "reduction": reduction, "max_dlogit": dev, "rel_dlogit": rel,
            })
            if not quiet:
                a = f"/a{act}" if act != "none" else ""
                print(f"[quant] {family:6s} ({arch}): w+c "
                      f"{w_mb + c_mb:7.2f} MB bf16 → {wq_mb + cq_mb:7.2f} MB "
                      f"{weights}/{cache_mode}{a} ({reduction:4.2f}×), "
                      f"max|Δlogit| {dev:.4f} (rel {rel:.3f})")
    best = {}
    for r in rows:
        best.setdefault(r["family"], 0.0)
        best[r["family"]] = max(best[r["family"]], r["reduction"])
    if not quiet:
        ok = all(v >= 2.0 for v in best.values())
        print(f"[quant] ≥2× weight+cache reduction on all four families: "
              f"{'YES' if ok else 'NO'} "
              f"({', '.join(f'{k} {v:.2f}×' for k, v in best.items())})")
    return rows


# -- self-speculative decoding report ----------------------------------------


def _decay_ranks(tree, g):
    """Geometric per-rank energy decay on every rank-bearing linear.

    Random-init factors have *flat* rank spectra (iid entries), so a
    truncated draft predicts almost nothing — trained BLAST/low-rank
    factors instead concentrate energy in the leading ranks (that is why
    rank truncation works at all).  The benchmark emulates a trained
    spectrum by scaling rank ρ by ``g**ρ``; both the plain and the
    speculative engine serve the *same* decayed model, so the comparison
    stays apples-to-apples.
    """
    if isinstance(tree, dict):
        kind = structures.rank_kind(tree)
        if kind is not None:
            key = "S" if kind == "blast" else "w_down"
            arr = tree[key]
            scale = g ** jnp.arange(arr.shape[-1], dtype=jnp.float32)
            return {**tree, key: arr * scale.astype(arr.dtype)}
        return {k: _decay_ranks(v, g) for k, v in tree.items()}
    return tree


# Per-family draft-rank fraction: MoE routing (mla) flips its top-k expert
# choice under heavier truncation, so its draft has to stay closer to the
# full model to keep the greedy agreement (and thus acceptance) up.
_SPEC_FRAC = {"gqa": 0.5, "mla": 0.7, "ssd": 0.4, "rglru": 0.25}


def speculative_report(quiet=False, k=7, frac=None, decay=0.5,
                       n_requests=4, slots=2, max_new=32):
    """End-to-end decode tok/s and acceptance rate, speculative vs plain.

    Decode-heavy workload (short prompts, long completions) per family.
    Reports the draft acceptance rate, tokens emitted per round, and the
    decode-throughput ratio against the same engine with speculation off.
    ``k=7`` keeps the verify chunk on the power-of-two bucket (k+1 = 8).

    The deepseek (mla) config gets its MoE ``capacity_factor`` raised so
    expert capacity never binds: capacity-based token dropping depends on
    the *batch shape* (the verify chunk packs k+1 columns per row where
    plain decode packs 1), so exact greedy equivalence — and a meaningful
    acceptance rate — requires the dropless regime.
    """
    rows = []
    for family, arch in FAMILIES.items():
        cfg = configs.ARCHS[arch].reduced()
        if cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        model = build_model(cfg)
        params = _decay_ranks(model.init(jax.random.PRNGKey(0)), decay)
        fam_frac = _SPEC_FRAC[family] if frac is None else frac
        key = jax.random.PRNGKey(2)

        def mk_reqs():
            reqs = []
            for i in range(n_requests):
                plen = 4 + (i * 3) % 5
                toks = jax.random.randint(jax.random.fold_in(key, i),
                                          (plen,), 0, cfg.vocab)
                reqs.append(Request(uid=i, prompt=[int(t) for t in toks],
                                    max_new_tokens=max_new))
            return reqs

        def serve(spec_k):
            eng = Engine(model, params, EngineConfig(
                scheduler=SchedulerConfig(slots=slots),
                memory=MemoryConfig(max_len=128),
                speculative=SpeculativeConfig(k=spec_k,
                                              draft_rank_frac=fam_frac)))
            for r in mk_reqs():
                eng.submit(r)
            eng.run()           # warm (compile) …
            for key_ in eng.stats:  # … drop compile time from the record
                eng.stats[key_] = ([] if isinstance(eng.stats[key_], list)
                                   else 0)
            for r in mk_reqs():
                eng.submit(r)
            done = eng.run()    # … then the timed workload on a hot engine
            assert len(done) == n_requests
            assert all(len(r.output) == max_new for r in done)
            return eng.throughput(), {r.uid: r.output for r in done}

        tp_plain, out_plain = serve(0)
        tp_spec, out_spec = serve(k)
        assert out_spec == out_plain, f"{family}: speculative != greedy"
        speedup = tp_spec["decode_tok_s"] / max(tp_plain["decode_tok_s"], 1e-9)
        rows.append({
            "family": family, "arch": arch, "k": k,
            "draft_rank_frac": fam_frac,
            "plain_decode_tok_s": tp_plain["decode_tok_s"],
            "spec_decode_tok_s": tp_spec["decode_tok_s"],
            "speedup": speedup,
            "acceptance_rate": tp_spec["acceptance_rate"],
            "tokens_per_round": tp_spec["tokens_per_round"],
        })
        if not quiet:
            print(f"[spec] {family:6s} ({arch}): k={k} f={fam_frac}: "
                  f"acceptance {tp_spec['acceptance_rate']:.2f}, "
                  f"{tp_spec['tokens_per_round']:.2f} tok/round, "
                  f"decode {tp_plain['decode_tok_s']:7.1f} → "
                  f"{tp_spec['decode_tok_s']:7.1f} tok/s "
                  f"({speedup:.2f}×)")
    if not quiet:
        best = max(rows, key=lambda r: r["speedup"])
        print(f"[spec] best end-to-end speedup: {best['family']} "
              f"{best['speedup']:.2f}× at acceptance "
              f"{best['acceptance_rate']:.2f}")
    return rows


# -- paged multi-tenant serving report ---------------------------------------


def make_trace(vocab, *, n_interactive=12, n_batch=4, shared_len=64,
               tail_len=4, interactive_new=6, batch_new=48, seed=3):
    """Mixed-tenant trace: a handful of long low-priority batch generations
    plus a stream of short interactive requests that all share one
    ``shared_len``-token system prompt.  Returns [(arrival_tick, factory)]
    — factories so FIFO and priority runs serve identical fresh requests.
    """
    key = jax.random.PRNGKey(seed)
    shared = [int(t) for t in
              jax.random.randint(key, (shared_len,), 0, vocab)]
    trace = []

    def req(uid, prompt, max_new, priority, prefix_len=None):
        return lambda: Request(uid=uid, prompt=list(prompt),
                               max_new_tokens=max_new, priority=priority,
                               prefix_len=prefix_len)

    for i in range(n_batch):
        toks = jax.random.randint(jax.random.fold_in(key, 100 + i),
                                  (8,), 0, vocab)
        trace.append((0, req(i, [int(t) for t in toks], batch_new, 1)))
    for i in range(n_interactive):
        tail = jax.random.randint(jax.random.fold_in(key, 200 + i),
                                  (tail_len,), 0, vocab)
        # staggered arrivals: the first interactive request computes and
        # registers the shared prefix, later ones hit it
        trace.append((4 + 3 * i,
                      req(100 + i, shared + [int(t) for t in tail],
                          interactive_new, 0, prefix_len=shared_len)))
    return trace


def _run_trace(model, params, trace, *, policy, pages, slots=4, max_len=128,
               page_size=16, chunk=16):
    eng = Engine(model, params, EngineConfig(
        scheduler=SchedulerConfig(slots=slots, chunk_size=chunk,
                                  policy=policy),
        memory=MemoryConfig(max_len=max_len, paged=True, page_size=page_size,
                            pages=pages)))
    peak = 0
    for timed in (False, True):   # warm pass compiles every step variant …
        if timed:                 # … so the timed pass measures scheduling
            for k, v in eng.stats.items():
                eng.stats[k] = [] if isinstance(v, list) else type(v)(0)
            eng.finished.clear()
        pending = sorted(trace, key=lambda e: e[0])
        i, tick = 0, 0
        while True:
            while i < len(pending) and pending[i][0] <= tick:
                eng.submit(pending[i][1]())
                i += 1
            more = eng.tick()
            if timed and eng._pc.has_paged:
                peak = max(peak, eng._pc.n_pages - 1 - eng._pc.pages.n_free)
            tick += 1
            if not more and i >= len(pending):
                break
    eng._pc.audit()
    return eng, peak


def paged_report(quiet=False, slots=4, max_len=128, page_size=16, pages=16):
    """Multi-tenant SLA report on the paged engine: FIFO vs priority
    scheduling over the same trace (shared-prefix interactive + long batch
    traffic), with the pool sized to force preemption.

    Reports TTFT/TPOT percentiles per priority class, preemption and
    prefix-hit rates, and the peak page residency against what slot-static
    allocation would have pinned (slots × max_len tokens).  Priority
    scheduling must improve interactive (class-0) TTFT over FIFO, and the
    shared system prompt must be stored once (prefix_hit_rate > 0).
    """
    cfg = configs.ARCHS["smollm-135m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = make_trace(cfg.vocab, n_batch=8)
    out = {"slots": slots, "max_len": max_len, "page_size": page_size,
           "pages": pages, "slot_static_tokens": slots * max_len}
    for policy in ("fifo", "priority"):
        eng, peak = _run_trace(model, params, trace, policy=policy,
                               pages=pages, slots=slots, max_len=max_len,
                               page_size=page_size)
        sla = eng.sla_report()
        out[policy] = {
            "sla": sla,
            "peak_pages": peak,
            "peak_page_tokens": peak * page_size,
            "requests": len(eng.finished),
        }
        if not quiet:
            c0 = sla["classes"].get("0", {})
            print(f"[paged] {policy:8s}: interactive TTFT p50 "
                  f"{(c0.get('ttft_p50_s') or 0) * 1e3:7.1f} ms / p99 "
                  f"{(c0.get('ttft_p99_s') or 0) * 1e3:7.1f} ms, "
                  f"preemptions {sla['preemptions']}, prefix-hit "
                  f"{sla['prefix_hit_rate']:.2f}, peak pages {peak}/{pages}")
    fifo_ttft = out["fifo"]["sla"]["classes"]["0"]["ttft_p50_s"]
    prio_ttft = out["priority"]["sla"]["classes"]["0"]["ttft_p50_s"]
    out["interactive_ttft_speedup"] = fifo_ttft / max(prio_ttft, 1e-9)
    if not quiet:
        print(f"[paged] priority vs FIFO interactive TTFT p50: "
              f"{out['interactive_ttft_speedup']:.2f}× better; pool "
              f"{(pages - 1) * page_size} tokens vs slot-static "
              f"{slots * max_len}")
    return out


# -- chaos / resilience report ------------------------------------------------


def chaos_report(quiet=False, slots=2, max_len=96, n_requests=6, max_new=16,
                 fault_spec="nan@5:u1;raise@10:u2;slow@3:0.4;drop@2:u3",
                 watchdog_s=0.15):
    """Serving under deterministic fault injection (serve/faults.py).

    Runs the same request mix twice on the hardest engine configuration
    (paged pool + self-speculative decoding): once fault-free for the
    greedy reference, once with the fault plan armed and the watchdog on.
    The headline guarantee this report pins: every request the plan does
    NOT target completes with byte-identical greedy output — a NaN'd row,
    a raising step, a stalled dispatch and a mid-stream client disconnect
    each stay contained to their own request.

    Reported per fault: detection-to-completion recovery latency (fault
    fire time → the targeted request leaving the system, by completion or
    isolation).  Plus degradation-ladder counts, watchdog trips, and
    goodput under faults (full-completion tokens/s, chaos vs clean)."""
    from repro.serve import ResilienceConfig

    cfg = configs.ARCHS["smollm-135m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(9)

    def mk_reqs():
        reqs = []
        for i in range(n_requests):
            plen = 4 + (i * 3) % 7
            toks = jax.random.randint(jax.random.fold_in(key, i), (plen,),
                                      0, cfg.vocab)
            reqs.append(Request(uid=i + 1, prompt=[int(t) for t in toks],
                                max_new_tokens=max_new))
        return reqs

    def mk_engine(spec=None, watchdog=None):
        return Engine(model, params, EngineConfig(
            scheduler=SchedulerConfig(slots=slots, chunk_size=8),
            memory=MemoryConfig(max_len=max_len, paged=True, page_size=8),
            speculative=SpeculativeConfig(k=3),
            resilience=ResilienceConfig(fault_spec=spec,
                                        watchdog_deadline_s=watchdog)))

    # fault-free reference pass
    eng0 = mk_engine()
    reqs0 = mk_reqs()
    for r in reqs0:
        eng0.submit(r)
    t0 = time.perf_counter()
    eng0.run()
    base_wall = time.perf_counter() - t0
    base = {r.uid: list(r.output) for r in reqs0}
    base_tokens = sum(len(o) for o in base.values())

    # chaos pass: engine-side faults fire from the plan's poll points; the
    # client-side drop_conn fault is simulated by cancelling the target
    # once it has streamed `events` tokens (exactly what the HTTP frontend
    # does when a disconnected client's next write fails)
    eng = mk_engine(fault_spec, watchdog_s)
    plan = eng.fault_plan
    drops = [f for f in plan.faults if f.kind == "drop_conn"]
    reqs = mk_reqs()
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    while eng.tick():
        for f in drops:
            if f.fired:
                continue
            req = next((r for r in reqs if r.uid == f.uid), None)
            if (req is not None and not req.done
                    and len(req.output) >= f.events):
                f.fired += 1
                plan.log.append({"kind": f.kind, "step": eng.stats["steps"],
                                 "uid": f.uid, "t": time.perf_counter(),
                                 "fault": f.describe()})
                eng.cancel(f.uid)
    wall = time.perf_counter() - t0
    eng.close()

    faulted = plan.faulted_uids()
    done_at = {r.uid: r.t_done for r in reqs}
    recoveries = []
    for e in plan.log:
        if e["uid"] is not None and done_at.get(e["uid"]) is not None:
            recoveries.append({"fault": e["fault"],
                               "uid": e["uid"],
                               "recovery_s": done_at[e["uid"]] - e["t"]})
    clean = [r for r in reqs if r.uid not in faulted]
    identical = all(list(r.output) == base[r.uid] for r in clean)
    assert identical, (
        "chaos broke a non-faulted request: "
        f"{ {r.uid: (r.output, base[r.uid]) for r in clean} }")
    good_tokens = sum(len(r.output) for r in reqs
                      if r.stop_reason == "length")
    res = eng.resilience_report()
    out = {
        "fault_spec": fault_spec,
        "watchdog_deadline_s": watchdog_s,
        "requests": n_requests,
        "faulted_uids": sorted(faulted),
        "non_faulted_token_identical": identical,
        "outcomes": {str(r.uid): {"stop_reason": r.stop_reason,
                                  "degrade_path": list(r.degrade_path),
                                  "tokens": len(r.output)}
                     for r in reqs},
        "recovery": recoveries,
        "recovery_p50_s": (float(np.percentile(
            [r["recovery_s"] for r in recoveries], 50))
            if recoveries else None),
        "faults_fired": res["faults"]["fired_by_kind"],
        "numeric_trips": res["numeric_trips"],
        "degrade_spec_off": res["degrade_spec_off"],
        "degrade_act_float": res["degrade_act_float"],
        "step_errors": res["step_errors"],
        "requeues": res["requeues"],
        "watchdog_trips": res["health"]["watchdog_trips"],
        "goodput_tok_s": good_tokens / wall,
        "clean_tok_s": base_tokens / base_wall,
        "goodput_ratio": (good_tokens / wall) / (base_tokens / base_wall),
    }
    if not quiet:
        print(f"[chaos] plan {fault_spec!r}: "
              f"{res['faults']['fired']} faults fired "
              f"({out['faults_fired']}), non-faulted token-identical: "
              f"{'YES' if identical else 'NO'}")
        print(f"[chaos] ladder: {out['numeric_trips']} trips "
              f"(spec_off {out['degrade_spec_off']}, act_float "
              f"{out['degrade_act_float']}), {out['step_errors']} step "
              f"errors, {out['requeues']} requeues, "
              f"{out['watchdog_trips']} watchdog trips")
        for r in recoveries:
            print(f"[chaos] recovery {r['fault']}: {r['recovery_s']*1e3:.0f} "
                  f"ms to contain uid {r['uid']}")
        print(f"[chaos] goodput under faults {out['goodput_tok_s']:.1f} "
              f"tok/s vs clean {out['clean_tok_s']:.1f} tok/s "
              f"({out['goodput_ratio']:.2f}×)")
    return out


# -- decode-step kernel-launch accounting ------------------------------------


def kernel_report(quiet=False, batch=2, max_len=32,
                  storages=("float", "int8", "int4")):
    """Structured-matmul launches per decode step, grouped vs per-projection,
    per weight-storage mode.

    Builds each family's reduced arch *unrolled* (scan_layers=False, so the
    eager dispatch count equals the runtime launch count — a scanned model
    traces its cycle body once) and executes one C=1 decode step through
    ``prefill_chunk`` with the grouped fast path on and off.  Every
    ``linear_apply`` / ``group_apply`` dispatch is one kernel launch on the
    Pallas path; grouping must never increase the count, and strictly
    decreases it for every family with a same-input bundle (GQA gate+up,
    MLA a-projections, RG-LRU input/gate pairs).

    The report is per storage mode because launch counts *are* per storage
    mode: before the grouped-q4 kernel, all-int4 bundles fell back to one
    launch per member, so int4 serving paid the full per-projection count.
    Now every storage mode must land on the same grouped count.
    """
    rows = []
    for family, arch in FAMILIES.items():
        cfg = configs.ARCHS[arch].reduced(scan_layers=False)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = jnp.ones((batch, 1), jnp.int32)
        steps = jnp.zeros((batch,), jnp.int32)
        n_tok = jnp.ones((batch,), jnp.int32)
        for storage in storages:
            if storage == "float":
                model_s, params_s = model, params
            else:
                qcfg = QuantConfig(weights=storage)
                cfg_q = dataclasses.replace(cfg, quant=qcfg)
                model_s = build_model(cfg_q)
                params_s = model_s.quantize_params(params, qcfg)
            cache = model_s.init_cache(batch, max_len)

            def count(enabled):
                with structures.grouping(enabled):
                    structures.reset_dispatch_count()
                    model_s.prefill_chunk(params_s, cache, tokens, steps,
                                          n_tok)
                    return structures.dispatch_count()

            grouped, loop = count(True), count(False)
            rows.append({"family": family, "arch": arch,
                         "layers": cfg.n_layers, "storage": storage,
                         "launches_grouped": grouped, "launches_loop": loop})
            if not quiet:
                mark = "<" if grouped < loop else "="
                print(f"[kernels] {family:6s} ({arch}) {storage:5s}: "
                      f"{grouped:3d} launches per decode step grouped "
                      f"{mark} {loop:3d} per-projection "
                      f"({cfg.n_layers} layers)")
    by_family: dict = {}
    for r in rows:
        by_family.setdefault(r["family"], {})[r["storage"]] = r
    for family, per in by_family.items():
        counts = {s: r["launches_grouped"] for s, r in per.items()}
        assert len(set(counts.values())) == 1, (
            f"{family}: grouped launch count differs across storage modes "
            f"{counts} — a quantized bundle fell off the grouped path")
    if not quiet:
        bundled = [r for r in rows if r["family"] in ("gqa", "mla", "rglru")]
        ok = all(r["launches_grouped"] < r["launches_loop"] for r in bundled)
        assert all(r["launches_grouped"] <= r["launches_loop"] for r in rows)
        print(f"[kernels] grouped launches strictly fewer on all bundled "
              f"families (every storage mode): {'YES' if ok else 'NO'}")
    return rows


# -- multi-chip mesh sweep (tensor-parallel serving) --------------------------


def _mesh_child(meshes=((1, 1), (1, 8)), max_new=12, n_requests=4):
    """Run inside the 8-fake-device subprocess: serve the same request mix
    on each mesh shape with the SAME engine code, assert token-identical
    greedy outputs, and print the sweep record as the last stdout line."""
    import json
    import sys

    from repro.launch.mesh import make_parallel, make_serving_mesh
    from repro.parallel import NO_PARALLEL
    from repro.roofline.analysis import collective_bytes

    cfg = configs.ARCHS["smollm-135m"].reduced(scan_layers=False)
    rec = {"family": "gqa", "arch": "smollm-135m",
           "devices_visible": len(jax.devices()), "meshes": []}
    outputs = {}
    for dp, tp in meshes:
        if dp * tp > len(jax.devices()):
            continue
        par = (NO_PARALLEL if (dp, tp) == (1, 1)
               else make_parallel(make_serving_mesh(dp, tp), serve=True))
        model = build_model(cfg, par)
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, EngineConfig(
            scheduler=SchedulerConfig(slots=4, chunk_size=8),
            memory=MemoryConfig(max_len=64), mesh=f"{dp},{tp}"))
        # per-shard grouped launches per decode step: under GSPMD/shard_map
        # every device executes the same partitioned program, so the
        # trace-time dispatch count IS the per-shard launch count
        tokens = jnp.ones((4, 1), jnp.int32)
        steps = jnp.zeros((4,), jnp.int32)
        n_tok = jnp.ones((4,), jnp.int32)
        with structures.grouping(True):
            structures.reset_dispatch_count()
            model.prefill_chunk(eng.params, eng.cache, tokens, steps, n_tok)
            launches = structures.dispatch_count()
        compiled = jax.jit(model.prefill_chunk).lower(
            eng.params, eng.cache, tokens, steps, n_tok).compile()
        coll, breakdown = collective_bytes(compiled.as_text())
        prompts = [r.prompt for r in
                   _mk_requests(n_requests, cfg.vocab, jax.random.PRNGKey(5),
                                prompt_len=16)]
        t0 = time.perf_counter()
        done = eng.generate_batch(prompts,
                                  SamplingParams(max_new_tokens=max_new))
        wall = time.perf_counter() - t0
        outputs[(dp, tp)] = {r.uid: list(r.output) for r in done}
        tp_stats = eng.throughput()
        total = sum(len(r.output) for r in done)
        srep = eng.sharding_report or {}
        rec["meshes"].append({
            "mesh": f"{dp}x{tp}", "dp": dp, "tp": tp, "devices": dp * tp,
            "tok_s": total / wall,
            "prefill_tok_s": tp_stats["prefill_tok_s"],
            "decode_tok_s": tp_stats["decode_tok_s"],
            "launches_per_decode_step_per_shard": launches,
            "collective_bytes_per_decode_step": coll,
            "collective_breakdown": breakdown,
            "replicated_param_bytes": srep.get("replicated_bytes", 0),
            "replicated_param_leaves": srep.get("replicated_leaves", 0),
            "param_bytes": srep.get("total_bytes", 0),
        })
    vals = list(outputs.values())
    rec["tokens_identical"] = all(v == vals[0] for v in vals[1:])
    assert rec["tokens_identical"], (
        "greedy outputs diverged across mesh shapes: "
        f"{ {k: v for k, v in outputs.items()} }")
    counts = {m["mesh"]: m["launches_per_decode_step_per_shard"]
              for m in rec["meshes"]}
    assert len(set(counts.values())) == 1, (
        f"per-shard launch count varies with mesh shape: {counts} — a "
        "bundle fell off the grouped path under sharding")
    print("MESH_SWEEP_JSON=" + json.dumps(rec))
    sys.stdout.flush()


def mesh_report(quiet=False, timeout=1800):
    """1-device vs 8-device (simulated) mesh sweep of the serving engine.

    Spawns a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_
    count=8`` (fake-device count must be set before jax initializes, so the
    parent process cannot run this in-line) and collects, per mesh shape:
    tok/s, per-shard grouped launches per decode step, per-device collective
    bytes per decode step (from the partitioned HLO), and the
    replicated-parameter bytes left by indivisible dims.  The child asserts
    greedy outputs are token-identical across mesh shapes — one engine from
    1 to 8 devices.
    """
    import json
    import os
    import subprocess
    import sys

    here = os.path.abspath(__file__)
    root = os.path.dirname(os.path.dirname(here))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run([sys.executable, here, "--mesh-child"],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(
            f"mesh sweep child failed (rc={proc.returncode}):\n"
            + proc.stdout[-2000:] + "\n" + proc.stderr[-4000:])
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("MESH_SWEEP_JSON=")][-1]
    rec = json.loads(line[len("MESH_SWEEP_JSON="):])
    if not quiet:
        for m in rec["meshes"]:
            print(f"[mesh] {m['mesh']:4s} ({m['devices']} devices): "
                  f"{m['tok_s']:7.1f} tok/s, "
                  f"{m['launches_per_decode_step_per_shard']:3d} launches"
                  f"/decode-step/shard, collective "
                  f"{m['collective_bytes_per_decode_step'] / 1e3:8.1f} KB"
                  f"/step, replicated params "
                  f"{m['replicated_param_bytes'] / 1e6:6.2f} MB")
        print(f"[mesh] greedy outputs token-identical across mesh shapes: "
              f"{'YES' if rec['tokens_identical'] else 'NO'}")
    return rec


# -- integer-vs-float per-call kernel timings ---------------------------------


def kernel_timing_report(quiet=False,
                         shapes=((1, 256, 256, 16, 32),
                                 (8, 256, 256, 16, 32),
                                 (128, 256, 256, 16, 32)),
                         reps=5):
    """Per-call wall time of one BLAST matmul across compute modes.

    Times the same (T, m, n, b, r) call in five modes — float, W8 (int8
    weights, float activations), W8A8, W4 and W4A8 — at decode shapes
    (T=1, T=8) and a chunked-prefill shape (T=128).  The integer-activation
    rows include the per-token quantize-act prologue inside the timed
    region, so `vs_float` is the honest end-to-end ratio a serving layer
    sees, not the bare contraction.  Uses the best-of-``reps`` protocol
    from kernels/autotune.py (compile + warm outside the timed region).
    """
    from repro.kernels import autotune as at
    from repro.kernels import ops

    backend = jax.default_backend()
    rows = []
    for (T, m, n, b, r) in shapes:
        key = jax.random.PRNGKey(7)
        kx, ku, ks, kv = jax.random.split(key, 4)
        p, q = m // b, n // b
        x = jax.random.normal(kx, (T, n), jnp.float32)
        U = jax.random.normal(ku, (b, p, r), jnp.float32)
        S = jax.random.normal(ks, (b, b, r), jnp.float32)
        V = jax.random.normal(kv, (b, q, r), jnp.float32)
        quantized = {}
        for bits, kind in ((8, "int8"), (4, "int4")):
            quantized[kind] = (qt.quantize(U, bits=bits, block_axes=(1, 2)),
                               qt.quantize(S, bits=bits, block_axes=(2,)),
                               qt.quantize(V, bits=bits, block_axes=(1, 2)))
        modes = [("float", lambda: ops.blast_matmul(x, U, S, V))]
        for kind, label_w, label_a in (("int8", "w8", "w8a8"),
                                       ("int4", "w4", "w4a8")):
            Uq, Sq, Vq = quantized[kind]
            modes.append((label_w,
                          lambda Uq=Uq, Sq=Sq, Vq=Vq:
                          ops.blast_matmul_q(x, Uq, Sq, Vq)))
            modes.append((label_a,
                          lambda Uq=Uq, Sq=Sq, Vq=Vq:
                          ops.blast_matmul_q(x, Uq, Sq, Vq, act="int8")))
        base_t = None
        for mode, fn in modes:
            dt = at._time_call(fn, reps=reps)
            if mode == "float":
                base_t = dt
            rows.append({"T": T, "m": m, "n": n, "b": b, "r": r,
                         "mode": mode, "backend": backend,
                         "time_s": dt, "vs_float": base_t / dt})
            if not quiet:
                print(f"[ktime] T={T:3d} m={m} n={n} b={b} r={r} "
                      f"{mode:5s}: {dt * 1e6:9.1f} µs "
                      f"({base_t / dt:5.2f}× vs float)")
    return rows


if __name__ == "__main__":
    import sys
    if "--mesh-child" in sys.argv:
        _mesh_child()
    else:
        run()
        quant_report()
        kernel_report()
        kernel_timing_report()
        speculative_report()
        mesh_report()
        paged_report()
        chaos_report()
