"""§Roofline report: renders the per-(arch × shape × mesh) table from the
dry-run artifacts in artifacts/dryrun/*.json — the three terms in seconds,
the dominant bottleneck, MODEL_FLOPS = 6·N_active·D (2·N·D for inference)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs."""

import glob
import json
import os

import jax
import numpy as np


def _active_params(arch_name: str) -> float:
    from repro import configs
    from repro.models import build_model
    cfg = configs.ARCHS[arch_name]
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = expert = 0
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        n = float(np.prod(leaf.shape))
        total += n
        keys = [getattr(p, "key", None) for p in path]
        # routed experts only: the shared expert (ffn/shared/*) and the
        # dense-warmup FFNs (pre_i/ffn/*) run for every token and must not
        # be discounted by top_k/E
        dense_prefix = any(isinstance(k, str) and k.startswith("pre_")
                           for k in keys)
        if (cfg.moe is not None and "ffn" in keys and "shared" not in keys
                and not dense_prefix and ("wi" in keys or "wo" in keys)):
            expert += n
    if cfg.moe is not None and expert:
        frac = cfg.moe.top_k / cfg.moe.n_experts
        return total - expert + expert * frac
    return total


def load_records(art_dir="artifacts/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run(art_dir="artifacts/dryrun", quiet=False, chips_default=256):
    recs = [r for r in load_records(art_dir) if r.get("status") == "ok"]
    if not recs:
        if not quiet:
            print("[roofline] no dry-run artifacts found — run "
                  "scripts/dryrun_sweep.sh first")
        return []
    cache: dict[str, float] = {}
    rows = []
    for r in recs:
        arch, shape = r["arch"], r["shape"]
        if arch not in cache:
            cache[arch] = _active_params(arch)
        n_active = cache[arch]
        t = r["roofline"]
        devices = r.get("devices", chips_default)
        train = shape.startswith("train")
        if shape.startswith("decode") or shape.startswith("long"):
            tokens = {"decode_32k": 128, "long_500k": 1}.get(shape, 128)
        else:
            tokens = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768}[shape]
        mf = (6.0 if train else 2.0) * n_active * tokens / devices
        ratio = mf / max(t["flops"], 1.0)
        rows.append({
            "arch": arch, "shape": shape, "mesh": r["mesh"],
            "t_compute_ms": t["t_compute"] * 1e3,
            "t_memory_ms": t["t_memory"] * 1e3,
            "t_collective_ms": t["t_collective"] * 1e3,
            "dominant": t["dominant"],
            "model_flops_ratio": ratio,
        })
        if not quiet:
            print(f"[roofline] {arch:22s} {shape:12s} {r['mesh']:8s} "
                  f"C {t['t_compute']*1e3:8.1f}ms "
                  f"M {t['t_memory']*1e3:8.1f}ms "
                  f"X {t['t_collective']*1e3:8.1f}ms "
                  f"→ {t['dominant']:10s} useful {ratio:6.1%}")
    return rows


if __name__ == "__main__":
    run()
