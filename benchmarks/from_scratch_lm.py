"""Paper Fig. 5 (GPT-2 WikiText-103 perplexity–FLOPs trade-off), offline
protocol: train a reduced GPT-2-family model from scratch on the synthetic
Markov LM stream with each structure at the same FLOPs budget; report final
loss vs relative FLOPs.  The paper's claim to reproduce: BLAST achieves the
best (or tied-best) loss-per-FLOP among the structured baselines."""

import dataclasses

import jax

from repro import configs
from repro.core.structures import StructureConfig
from repro.data import TokenStream
from repro.models import build_model
from repro.optim import adamw, cosine_schedule
from repro.train import Trainer
from benchmarks.flops_table import model_linear_flops


class _Data:
    def __init__(self, cfg, batch, seq):
        self.stream = TokenStream(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch)

    def batch(self, step):
        return self.stream.batch(step)


def run(steps=150, batch=16, seq=64, quiet=False):
    base = configs.ARCHS["gpt2-blast"].reduced(
        vocab=256, d_model=128, n_layers=4, d_ff=256, n_heads=4, n_kv_heads=4,
        head_dim=32)
    dense_flops = model_linear_flops(base, StructureConfig(kind="dense"))
    rows = []
    structures = [
        StructureConfig(kind="dense"),
        StructureConfig(kind="blast", b=4, keep_ratio=0.5),
        StructureConfig(kind="low_rank", keep_ratio=0.5),
        StructureConfig(kind="monarch", b=4, keep_ratio=0.5),
        StructureConfig(kind="block_diag", b=4, keep_ratio=0.5),
    ]
    for st in structures:
        cfg = dataclasses.replace(base, structure=st, structure_ffn=None)
        model = build_model(cfg)
        trainer = Trainer(model, adamw(cosine_schedule(3e-3, steps, 10)),
                          _Data(cfg, batch, seq), log_every=10_000)
        out = trainer.run(steps, key=jax.random.PRNGKey(0))
        rel = 100.0 * model_linear_flops(cfg, st) / dense_flops
        final = sum(out["history"][-10:]) / 10
        rows.append({"kind": st.kind, "rel_flops_pct": rel,
                     "final_loss": final})
        if not quiet:
            print(f"[fig5] {st.kind:10s} rel FLOPs {rel:6.1f}% "
                  f"final loss {final:.4f}")
    return rows


if __name__ == "__main__":
    run()
