"""Parallelism context threaded from the launcher into the models.

Models never import from ``launch``; they receive a ``Parallel`` describing
the mesh axes so that (i) activation sharding constraints and (ii) the MoE
expert-parallel ``shard_map`` region can be emitted.  With ``mesh=None``
(unit tests, single-CPU smoke runs) every helper is a no-op and the MoE
layer uses the identical dispatch math without collectives.

Axis roles
----------
``data_axes``   activation-batch axes — ("pod", "data") multi-pod, ("data",)
                single-pod.  DP gradient reduction happens over these.
``fsdp_axis``   parameter/optimizer-state sharding axis (zero-3); we reuse
                the "data" mesh axis, the standard TPU recipe.
``model_axis``  tensor-parallel / expert-parallel axis ("model").
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Parallel:
    mesh: Mesh | None = None
    data_axes: tuple[str, ...] = ()
    fsdp_axis: str | None = None
    model_axis: str | None = None
    # parameter-sharding (zero-3) axes.  None → same as data_axes.  Serving
    # passes () so decode never pays a per-token parameter all-gather
    # (§Perf iteration 4): TP-sharded + data-replicated params, the standard
    # inference layout.
    fsdp_axes_override: tuple[str, ...] | None = None

    @property
    def fsdp_axes(self) -> tuple[str, ...]:
        if self.fsdp_axes_override is not None:
            return self.fsdp_axes_override
        return self.data_axes

    @property
    def active(self) -> bool:
        return self.mesh is not None

    @property
    def dp_size(self) -> int:
        if not self.active:
            return 1
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp_size(self) -> int:
        if not self.active or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    def batch_spec(self, *rest) -> P:
        """PartitionSpec for a batch-leading activation."""
        lead = self.data_axes if self.data_axes else None
        return P(lead, *rest)

    def constraint(self, x: jax.Array, spec: P) -> jax.Array:
        if not self.active:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def shard_batch(self, x: jax.Array) -> jax.Array:
        """Constrain a (B, ...) activation to be batch-sharded."""
        if not self.active:
            return x
        rest = (None,) * (x.ndim - 1)
        return self.constraint(x, self.batch_spec(*rest))


NO_PARALLEL = Parallel()
