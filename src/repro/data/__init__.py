from repro.data.synthetic import (  # noqa: F401
    TokenStream, classification_batch, lm_batch, patches_batch)
