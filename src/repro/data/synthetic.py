"""Deterministic synthetic data pipeline (offline container — no real
corpora).  Everything is *counter-indexed*: ``batch(step)`` is a pure
function of (seed, step, shard), so

  * restarts recompute exactly the batch they would have seen (checkpoint
    restore replays nothing);
  * a relocated/elastic worker regenerates its shard with zero coordination
    — the straggler-mitigation story in DESIGN.md §4;
  * data order is bitwise-reproducible across runs and meshes.

The LM stream is a noisy Markov chain over a random permutation: token
``t+1`` is ``perm[token_t]`` with prob 0.9 else uniform — low entropy floor
(≈ 0.1·log V + H(0.1)), learnable by even small models, so training-loss
benchmarks have a meaningful signal.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """→ {"tokens": (B/n_shards, S+1)} for the given shard."""
        assert self.global_batch % n_shards == 0
        b = self.global_batch // n_shards
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        key = jax.random.fold_in(key, shard)
        tokens = _markov_tokens(key, b, self.seq_len + 1, self.vocab, self.noise)
        return {"tokens": tokens}


def _markov_tokens(key, batch: int, length: int, vocab: int, noise: float):
    kp, k0, kn, kr = jax.random.split(key, 4)
    # vocab-seeded permutation — same chain for every batch/shard/step
    perm = jax.random.permutation(jax.random.PRNGKey(vocab), vocab)
    x0 = jax.random.randint(k0, (batch,), 0, vocab)
    flip = jax.random.uniform(kn, (batch, length)) < noise
    rnd = jax.random.randint(kr, (batch, length), 0, vocab)

    def step(x, inp):
        f, r = inp
        nxt = jnp.where(f, r, perm[x])
        return nxt, nxt

    _, seq = jax.lax.scan(step, x0, (flip.T, rnd.T))
    return seq.T.astype(jnp.int32)


def lm_batch(key, batch: int, seq_len: int, vocab: int, noise: float = 0.1):
    """One-off LM batch (tests): (tokens (B, S+1))."""
    return _markov_tokens(key, batch, seq_len + 1, vocab, noise)


def classification_batch(key, batch: int, n_patches: int, patch_dim: int,
                         n_classes: int, noise: float = 0.3):
    """ViT-style synthetic classification: class templates + Gaussian noise."""
    kt, kc, kn = jax.random.split(key, 3)
    templates = jax.random.normal(
        jax.random.fold_in(kt, n_classes), (n_classes, n_patches, patch_dim))
    labels = jax.random.randint(kc, (batch,), 0, n_classes)
    x = templates[labels] + noise * jax.random.normal(
        kn, (batch, n_patches, patch_dim))
    return x, labels


def patches_batch(key, batch: int, n_patches: int, patch_dim: int):
    """Stub-frontend embeddings (llava / whisper frames)."""
    return jax.random.normal(key, (batch, n_patches, patch_dim))
