"""Fault-tolerant checkpointing: atomic commit, integrity hashes, async
writes, and **elastic restore** onto a different mesh.

Layout:  <dir>/step_<n>/
            manifest.json       tree structure, shapes, dtypes, hashes, step
            <leafpath>.npy      one file per leaf (paths are '/'-joined keys)

Writes go to ``step_<n>.tmp`` and are renamed only after the manifest (which
is written last) is fsync'd — a killed writer never leaves a checkpoint that
``latest_step`` would pick up.  ``restore`` takes an optional tree of
``jax.sharding.NamedSharding`` (or a target mesh + spec fn) and
``jax.device_put``s each leaf, so a checkpoint saved on a 16×16 mesh reshards
transparently onto 2×16×16 (or 1 CPU) — the elastic-scaling story.

Single-process container note: leaves are gathered to host before writing.
On a real multi-host pod this module is the *coordinator-side* format; the
per-host sharded variant writes `leaf.<shard>.npy` slices with the same
manifest (shard_index recorded) — the restore path already handles both via
``np.load`` + ``device_put``.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Callable

import jax
import numpy as np

from repro.quant import QArray

SEP = "/"


def _flatten(tree, prefix="") -> dict[str, Any]:
    out = {}
    if isinstance(tree, QArray):
        # quantized leaf: two array files; bits / packing are static and
        # come back from the restore skeleton
        out[f"{prefix}q"] = tree.q
        out[f"{prefix}scale"] = tree.scale
    elif isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{SEP}"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(skeleton, flat: dict[str, np.ndarray], prefix=""):
    if isinstance(skeleton, QArray):
        return QArray(q=flat[f"{prefix}q"], scale=flat[f"{prefix}scale"],
                      bits=skeleton.bits, last_dim=skeleton.last_dim)
    if isinstance(skeleton, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}{SEP}")
                for k, v in skeleton.items()}
    if isinstance(skeleton, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}{SEP}")
                for i, v in enumerate(skeleton)]
        return type(skeleton)(vals) if not hasattr(skeleton, "_fields") \
            else type(skeleton)(*vals)
    if skeleton is None:
        return None
    return flat[prefix[:-1]]


def save(directory: str, step: int, tree, *, hash_leaves: bool = True) -> str:
    """Atomic checkpoint write.  Returns the committed path."""
    flat = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        true_dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":  # bf16 / fp8 — npy can't roundtrip
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        fname = path.replace(SEP, "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        digest = (hashlib.sha256(arr.tobytes()).hexdigest()[:16]
                  if hash_leaves else "")
        manifest["leaves"][path] = {
            "file": fname, "shape": list(arr.shape), "dtype": true_dtype,
            "sha256_16": digest,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, step: int, skeleton, *,
            shardings=None, verify: bool = True):
    """Load ``step`` into the structure of ``skeleton``.

    ``shardings``: optional pytree (congruent with skeleton) of
    ``NamedSharding``/``SingleDeviceSharding`` — each leaf is device_put with
    its target sharding, which is how a checkpoint moves between meshes.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    flat = {}
    for leaf_path, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(path, meta["file"]))
        if verify and meta["sha256_16"]:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
            if digest != meta["sha256_16"]:
                raise IOError(f"checkpoint corruption in {leaf_path}")
        if str(arr.dtype) != meta["dtype"]:  # stored as a uint view
            import ml_dtypes  # noqa: F401  (registers bf16/fp8 dtypes)
            arr = arr.view(np.dtype(meta["dtype"]))
        sh = flat_shard.get(leaf_path)
        flat[leaf_path] = jax.device_put(arr, sh) if sh is not None else arr
    return _unflatten_into(skeleton, flat)


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async commit."""

    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree):
        self.wait()
        # device_get on the main thread (arrays may be donated after return)
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            save(self.directory, step, host_tree, hash_leaves=True)
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, skeleton, shardings=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return restore(self.directory, step, skeleton,
                       shardings=shardings), step
