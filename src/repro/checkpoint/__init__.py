from repro.checkpoint.store import (  # noqa: F401
    CheckpointManager, latest_step, restore, save)
