from repro.roofline.analysis import (  # noqa: F401
    HW_V5E, RooflineTerms, analyze_compiled, collective_bytes, model_flops)
