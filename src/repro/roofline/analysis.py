"""Three-term roofline from a compiled (dry-run) artifact.

    compute   = HLO_FLOPs_per_device / peak_FLOP/s
    memory    = HLO_bytes_per_device / HBM_bw
    collective= collective_bytes_per_device / link_bw

``cost_analysis`` supplies per-device FLOPs/bytes (the compiled module is
the post-SPMD per-partition program).  Collective bytes are NOT in
cost_analysis: we parse the partitioned HLO text and sum the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (ring all-reduce counted 2× — reduce-scatter +
all-gather wire traffic).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float      # FLOP/s (bf16)
    hbm_bw: float          # B/s
    link_bw: float         # B/s per ICI link
    hbm_bytes: float       # device memory


HW_V5E = Hardware(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                  link_bw=50e9, hbm_bytes=16e9)


@dataclasses.dataclass
class RooflineTerms:
    flops: float                  # per device
    bytes_accessed: float         # per device
    coll_bytes: float             # per device (wire estimate)
    coll_breakdown: dict          # op kind -> bytes
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap model: bound = max of the three engines."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self) -> dict:
        return {**dataclasses.asdict(self), "dominant": self.dominant,
                "step_time": self.step_time}


# one HLO result type like  f32[8,128,4096]  or bf16[16]{0}
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\((.*)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> tuple[float, dict]:
    """Per-device wire-byte estimate from partitioned HLO text (ring models):

      all-reduce       2 × buffer      (reduce-scatter + all-gather phases)
      all-gather       1 × result      (receives (n−1)/n of the full result)
      reduce-scatter   1 × operand     (sends (n−1)/n of the full operand)
      all-to-all       1 × result
      collective-permute 1 × result
    """
    breakdown: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        result_type, kind, rest = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(result_type)
        if kind == "reduce-scatter":
            # result is 1/n of the operand; wire ≈ full operand
            b *= _group_size(rest)
        wire = 2 * b if kind == "all-reduce" else b
        breakdown[kind] = breakdown.get(kind, 0.0) + wire
    return sum(breakdown.values()), breakdown


_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(rest: str) -> int:
    m = _GROUPS_ARR_RE.search(rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_SET_RE.search(rest)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def analyze_compiled(compiled, hw: Hardware = HW_V5E) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    # sum every "bytes accessed{...}" bucket if the total key is absent
    if "bytes accessed" in cost:
        bytes_accessed = float(cost["bytes accessed"])
    else:
        bytes_accessed = sum(float(v) for k, v in cost.items()
                             if k.startswith("bytes accessed"))
    coll, breakdown = collective_bytes(compiled.as_text())
    return RooflineTerms(
        flops=flops,
        bytes_accessed=bytes_accessed,
        coll_bytes=coll,
        coll_breakdown=breakdown,
        t_compute=flops / hw.peak_flops,
        t_memory=bytes_accessed / hw.hbm_bw,
        t_collective=coll / hw.link_bw,
    )


def model_flops(n_params_active: float, tokens: float,
                train: bool) -> float:
    """6·N·D (train: fwd 2ND + bwd 4ND); inference fwd only = 2·N·D."""
    return (6.0 if train else 2.0) * n_params_active * tokens


def save_report(path: str, report: dict):
    with open(path, "w") as f:
        json.dump(report, f, indent=1, default=float)
