"""Jit'd public wrappers around the Pallas kernels: shape padding, block-size
selection (VMEM budgeting), CPU interpret fallback, and the XLA einsum path
used under GSPMD (pjit shards the einsum chain; the Pallas path is for
shard_map-per-device execution on real TPUs)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import quant as qt
from repro.kernels import ref
from repro.kernels.blast_matmul import (blast_matmul_pallas,
                                        blast_matmul_q_pallas)
from repro.kernels.flash_attention import (flash_attention_pallas,
                                           flash_attention_prefill_pallas)

# v5e VMEM is 16MB less a safety margin for double buffering.
_VMEM_BUDGET = 8 * 1024 * 1024


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_blast_blocks(T: int, m: int, n: int, b: int, r: int,
                      bytes_per_el: int = 4,
                      factor_bytes: int | None = None) -> tuple[int, int]:
    """Choose (block_t, block_r) so the VMEM resident set fits the budget.

    Resident set ≈ x-tile (t·n) + z (b·t·r_t) + y-acc (t·m, fp32) +
    U tile (p·r_t) + S (b²·r_t) + V (b·q·r_t).  ``factor_bytes`` sizes the
    U/S/V terms when they differ from the activations (int8 factors with
    float x); it defaults to ``bytes_per_el``.
    """
    p, q = m // b, n // b
    fb = bytes_per_el if factor_bytes is None else factor_bytes
    block_t, block_r = 128, 128
    while block_t > 8:
        for br in (128, 64, 32):
            resident = (
                block_t * n * bytes_per_el
                + b * block_t * br * 4
                + block_t * m * 4
                + p * br * fb
                + b * b * br * fb
                + b * q * br * fb
            )
            if resident <= _VMEM_BUDGET:
                return block_t, br
        block_t //= 2
    return 8, 32


def _blast_tiled(x, U, S, V, block_t, block_r, factor_bytes, call):
    """Shared wrapper scaffold for the fused BLAST kernels: flatten leading
    dims, pick VMEM-fitting tiles, pad T and r to block multiples, invoke
    ``call(xf, U, S, V, block_t, block_r)``, unpad."""
    b, p, r = U.shape
    q = V.shape[1]
    m, n = b * p, b * q
    lead = x.shape[:-1]
    T = 1
    for d in lead:
        T *= d
    xf = x.reshape(T, n)
    if block_t is None or block_r is None:
        bt, br = pick_blast_blocks(T, m, n, b, r, x.dtype.itemsize,
                                   factor_bytes)
        block_t = block_t or min(bt, _round_up(T, 8))
        block_r = block_r or min(br, _round_up(r, 8))
    T_pad = _round_up(T, block_t)
    r_pad = _round_up(r, block_r)
    if T_pad != T:
        xf = jnp.pad(xf, ((0, T_pad - T), (0, 0)))
    if r_pad != r:
        pad = ((0, 0), (0, 0), (0, r_pad - r))
        U, S, V = jnp.pad(U, pad), jnp.pad(S, pad), jnp.pad(V, pad)
    y = call(xf, U, S, V, block_t, block_r)
    return y[:T].reshape(*lead, m)


@functools.partial(jax.jit, static_argnames=("block_t", "block_r", "interpret", "use_pallas"))
def blast_matmul(
    x: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    *,
    block_t: int | None = None,
    block_r: int | None = None,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jax.Array:
    """x: (..., n) → (..., m).  Pads T and r to block multiples."""
    if not use_pallas:
        return ref.blast_matmul_ref(x, U, S, V)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _blast_tiled(
        x, U, S, V, block_t, block_r, x.dtype.itemsize,
        lambda xf, Up, Sp, Vp, bt, br: blast_matmul_pallas(
            xf, Up, Sp, Vp, block_t=bt, block_r=br, interpret=interpret))


@functools.partial(jax.jit, static_argnames=("block_t", "block_r", "interpret", "use_pallas"))
def blast_matmul_q(
    x: jax.Array,
    Uq: "qt.QArray",
    Sq: "qt.QArray",
    Vq: "qt.QArray",
    *,
    block_t: int | None = None,
    block_r: int | None = None,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jax.Array:
    """Quantized-factor BLAST matmul: x (..., n) → (..., m).

    Takes the per-block ``QArray`` factors produced by the blast
    ``LinearSpec.quantize`` (U/V: one scale per block, S: one per coupling
    vector — folded to a per-(i, j) scalar grid for the kernel).  int4
    factors are unpacked to int8 codes on entry (the nibble-packed kernel
    path is an open item); scales ride in via scalar prefetch.
    """
    b = Uq.q.shape[0]
    U8, S8, V8 = qt.int_values(Uq), qt.int_values(Sq), qt.int_values(Vq)
    su = Uq.scale.reshape(b)
    ss = Sq.scale.reshape(b, b)
    sv = Vq.scale.reshape(b)
    if not use_pallas:
        return ref.blast_matmul_q_ref(x, U8, S8, V8, su, ss, sv)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _blast_tiled(  # int8 factors: 1 byte/element in VMEM
        x, U8, S8, V8, block_t, block_r, 1,
        lambda xf, Up, Sp, Vp, bt, br: blast_matmul_q_pallas(
            xf, Up, Sp, Vp, su, ss, sv, block_t=bt, block_r=br,
            interpret=interpret))


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_kv", "interpret", "use_pallas"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jax.Array:
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D) → (B, Hq, T, D)."""
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, Hq, T, D = q.shape
    S_len = k.shape[2]
    block_q = min(block_q, _round_up(T, 8))
    block_kv = min(block_kv, _round_up(S_len, 8))
    T_pad = _round_up(T, block_q)
    S_pad = _round_up(S_len, block_kv)
    if T_pad != T:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, T_pad - T), (0, 0)))
    if S_pad != S_len:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, S_pad - S_len), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, S_pad - S_len), (0, 0)))
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        kv_len=S_len, block_q=block_q, block_kv=block_kv, interpret=interpret)
    return out[:, :, :T, :]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret", "use_pallas"))
def flash_attention_prefill(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_offsets: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jax.Array:
    """Chunked-prefill attention at per-sequence offsets (continuous batching).

    q: (B, Hq, C, D) — one C-token chunk per row; k, v: (B, Hkv, S, D) — the
    positional KV cache (chunk keys already written at their absolute slots);
    q_offsets: (B,) int32 first-token position per row.  The causal mask is
    shifted by each row's offset — the C×max_len prefill step of the serving
    engine's mixed batches.
    """
    if not use_pallas:
        return ref.attention_prefill_ref(q, k, v, q_offsets, causal=causal,
                                         window=window)
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, Hq, T, D = q.shape
    S_len = k.shape[2]
    block_q = min(block_q, _round_up(T, 8))
    block_kv = min(block_kv, _round_up(S_len, 8))
    T_pad = _round_up(T, block_q)
    S_pad = _round_up(S_len, block_kv)
    if T_pad != T:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, T_pad - T), (0, 0)))
    if S_pad != S_len:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, S_pad - S_len), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, S_pad - S_len), (0, 0)))
    out = flash_attention_prefill_pallas(
        q, k, v, q_offsets, causal=causal, window=window, kv_len=S_len,
        block_q=block_q, block_kv=block_kv, interpret=interpret)
    return out[:, :, :T, :]
