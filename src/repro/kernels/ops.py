"""Jit'd public wrappers around the Pallas kernels: shape padding, block-size
selection (VMEM budgeting + optional measured autotune cache), CPU interpret
fallback, and the XLA einsum path used under GSPMD (pjit shards the einsum
chain; the Pallas path is for shard_map-per-device execution on real TPUs)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import quant as qt
from repro.kernels import autotune, ref
from repro.kernels.blast_matmul import (blast_matmul_grouped_pallas,
                                        blast_matmul_grouped_q4_pallas,
                                        blast_matmul_grouped_q_pallas,
                                        blast_matmul_grouped_w4a8_pallas,
                                        blast_matmul_grouped_w8a8_pallas,
                                        blast_matmul_pallas,
                                        blast_matmul_q4_pallas,
                                        blast_matmul_q_pallas,
                                        blast_matmul_w4a8_pallas,
                                        blast_matmul_w8a8_pallas)
from repro.kernels.flash_attention import (flash_attention_pallas,
                                           flash_attention_prefill_pallas)

# v5e VMEM is 16MB less a safety margin for double buffering.
_VMEM_BUDGET = 8 * 1024 * 1024


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_blast_blocks(T: int, m: int, n: int, b: int, r: int,
                      bytes_per_el: int = 4,
                      factor_bytes: float | None = None) -> tuple[int, int]:
    """Choose (block_t, block_r) so the VMEM resident set fits the budget.

    Resident set ≈ x-tile (t·n) + z (b·t·r_t) + y-acc (t·m, fp32) +
    U tile (p·r_t) + S (b²·r_t) + V (b·q·r_t).  ``factor_bytes`` sizes the
    U/S/V terms when they differ from the activations (int8 factors with
    float x, 0.5 for nibble-packed int4); it defaults to ``bytes_per_el``.

    Candidate ``block_t`` starts at the call's actual (rounded-up) T, not a
    flat 128: a decode-sized T=1..8 call must not budget VMEM for 128-row
    tiles it never materializes — that used to force needlessly small
    ``block_r`` for skinny calls.
    """
    p, q = m // b, n // b
    fb = bytes_per_el if factor_bytes is None else factor_bytes
    block_t = min(128, _round_up(max(T, 1), 8))
    while True:
        for br in (128, 64, 32):
            resident = (
                block_t * n * bytes_per_el
                + b * block_t * br * 4
                + block_t * m * 4
                + int((p * br + b * b * br + b * q * br) * fb)
            )
            if resident <= _VMEM_BUDGET:
                return block_t, br
        if block_t <= 16:
            break
        block_t //= 2
    return 8, 32


def _resolve_blocks(block_t: int | None, block_r: int | None, T: int, m: int,
                    n: int, b: int, r: int, x_dtype, factor_bytes,
                    G: int, kind: str, act: str = "none") -> tuple[int, int]:
    """Explicit blocks win; else the autotune cache (when enabled); else the
    VMEM heuristic.  All inputs are trace-time statics.  ``act`` is the
    activation storage ("none" | "int8") — part of the autotune key, since
    int8 x-tiles shift the VMEM balance and the MXU path entirely."""
    if block_t is not None and block_r is not None:
        return block_t, block_r
    x_bytes = 1 if act == "int8" else jnp.dtype(x_dtype).itemsize
    hit = autotune.lookup(autotune.Key(
        T=T, m=m, n=n, b=b, r=r, G=G, dtype=jnp.dtype(x_dtype).name,
        kind=kind, backend=jax.default_backend(), act=act))
    if hit is not None:
        bt, br = hit
    else:
        bt, br = pick_blast_blocks(T, m, n, b, r, x_bytes, factor_bytes)
    block_t = block_t or min(bt, _round_up(T, 8))
    block_r = block_r or min(br, _round_up(r, 8))
    return block_t, block_r


def _flatten_x(x: jax.Array) -> tuple[jax.Array, tuple[int, ...], int]:
    lead = x.shape[:-1]
    T = 1
    for d in lead:
        T *= d
    return x.reshape(T, x.shape[-1]), lead, T


def _pad_t(xf: jax.Array, T: int, block_t: int) -> tuple[jax.Array, int]:
    T_pad = _round_up(T, block_t)
    if T_pad != T:
        xf = jnp.pad(xf, ((0, T_pad - T), (0, 0)))
    return xf, T_pad


def _pad_last(a: jax.Array, target: int) -> jax.Array:
    """Zero-pad the trailing (rank or packed-rank) axis — exact for BLAST:
    padded ranks / zero nibble codes contribute nothing to the contraction."""
    if a.shape[-1] == target:
        return a
    return jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, target - a.shape[-1])])


@functools.partial(jax.jit, static_argnames=("block_t", "block_r", "interpret", "use_pallas"))
def blast_matmul(
    x: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    *,
    block_t: int | None = None,
    block_r: int | None = None,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jax.Array:
    """x: (..., n) → (..., m).  Pads T and r to block multiples."""
    if not use_pallas:
        return ref.blast_matmul_ref(x, U, S, V)
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, p, r = U.shape
    q = V.shape[1]
    m, n = b * p, b * q
    xf, lead, T = _flatten_x(x)
    block_t, block_r = _resolve_blocks(block_t, block_r, T, m, n, b, r,
                                       x.dtype, x.dtype.itemsize, 1, "float")
    xf, _ = _pad_t(xf, T, block_t)
    r_pad = _round_up(r, block_r)
    U, S, V = (_pad_last(a, r_pad) for a in (U, S, V))
    y = blast_matmul_pallas(xf, U, S, V, block_t=block_t, block_r=block_r,
                            interpret=interpret)
    return y[:T].reshape(*lead, m)


@functools.partial(jax.jit, static_argnames=("block_t", "block_r", "interpret", "use_pallas"))
def blast_matmul_grouped(
    x: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    *,
    block_t: int | None = None,
    block_r: int | None = None,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jax.Array:
    """Grouped BLAST matmul: G congruent factor sets over one shared input.

    x: (..., n); U (G,b,p,r), S (G,b,b,r), V (G,b,q,r) → (G, ..., m) in one
    kernel launch (one x-tile load amortized over the whole group).
    """
    if not use_pallas:
        return ref.blast_matmul_grouped_ref(x, U, S, V)
    interpret = (not _on_tpu()) if interpret is None else interpret
    G, b, p, r = U.shape
    q = V.shape[2]
    m, n = b * p, b * q
    xf, lead, T = _flatten_x(x)
    block_t, block_r = _resolve_blocks(block_t, block_r, T, m, n, b, r,
                                       x.dtype, x.dtype.itemsize, G, "float")
    xf, _ = _pad_t(xf, T, block_t)
    r_pad = _round_up(r, block_r)
    U, S, V = (_pad_last(a, r_pad) for a in (U, S, V))
    y = blast_matmul_grouped_pallas(xf, U, S, V, block_t=block_t,
                                    block_r=block_r, interpret=interpret)
    return y[:, :T].reshape(G, *lead, m)


def _quantize_pad_x(xf: jax.Array, T: int,
                    block_t: int) -> tuple[jax.Array, jax.Array]:
    """Fused kernel prologue for the integer-activation path: per-token int8
    quantize of the flattened input, then zero-pad codes AND scales to the
    T block multiple (zero codes × zero scale dequantize to exactly 0)."""
    xq, sx = qt.quantize_act(xf)
    T_pad = _round_up(T, block_t)
    if T_pad != T:
        xq = jnp.pad(xq, ((0, T_pad - T), (0, 0)))
        sx = jnp.pad(sx, ((0, T_pad - T), (0, 0)))
    return xq, sx


@functools.partial(jax.jit, static_argnames=("block_t", "block_r",
                                             "interpret", "use_pallas", "act"))
def blast_matmul_q(
    x: jax.Array,
    Uq: "qt.QArray",
    Sq: "qt.QArray",
    Vq: "qt.QArray",
    *,
    block_t: int | None = None,
    block_r: int | None = None,
    interpret: bool | None = None,
    use_pallas: bool = True,
    act: str = "none",
) -> jax.Array:
    """Quantized-factor BLAST matmul: x (..., n) → (..., m).

    Takes the per-block ``QArray`` factors produced by the blast
    ``LinearSpec.quantize`` (U/V: one scale per block, S: one per coupling
    vector — folded to a per-(i, j) scalar grid for the kernel); scales ride
    in via scalar prefetch.  int8 factors feed the fused int8 kernel; int4
    factors stay *nibble-packed* all the way into VMEM and dispatch to
    ``blast_matmul_q4_pallas`` (half the U/S/V HBM reads again) — the packed
    uint8 arrays are the pallas_call operands, no int8 materialization.

    ``act="int8"`` selects the true integer-compute path (W8A8 / W4A8): x
    is quantized per token inside this jitted wrapper (one fused prologue
    per layer input) and stage 1 contracts codes in int32.
    """
    b = Uq.q.shape[0]
    su = Uq.scale.reshape(b)
    ss = Sq.scale.reshape(b, b)
    sv = Vq.scale.reshape(b)
    bits = {Uq.bits, Sq.bits, Vq.bits}
    if not use_pallas:
        U8, S8, V8 = (qt.int_values(a) for a in (Uq, Sq, Vq))
        if act == "int8":
            xf, lead, T = _flatten_x(x)
            xq, sx = qt.quantize_act(xf)
            y = ref.blast_matmul_a8_ref(xq, sx, U8, S8, V8, su, ss, sv)
            return y.reshape(*lead, b * U8.shape[1]).astype(x.dtype)
        return ref.blast_matmul_q_ref(x, U8, S8, V8, su, ss, sv)
    interpret = (not _on_tpu()) if interpret is None else interpret
    if bits == {4}:
        b, p, r = Uq.shape            # logical (unpacked) factor shape
        q = Vq.shape[1]
        m, n = b * p, b * q
        xf, lead, T = _flatten_x(x)
        block_t, block_r = _resolve_blocks(block_t, block_r, T, m, n, b, r,
                                           x.dtype, 0.5, 1, "int4", act)
        r_pad = _round_up(r, block_r)
        Up, Sp, Vp = (_pad_last(a.q, r_pad // 2) for a in (Uq, Sq, Vq))
        if act == "int8":
            xq, sx = _quantize_pad_x(xf, T, block_t)
            y = blast_matmul_w4a8_pallas(xq, sx, Up, Sp, Vp, su, ss, sv,
                                         block_t=block_t, block_r=block_r,
                                         interpret=interpret,
                                         out_dtype=x.dtype)
        else:
            xf, _ = _pad_t(xf, T, block_t)
            y = blast_matmul_q4_pallas(xf, Up, Sp, Vp, su, ss, sv,
                                       block_t=block_t, block_r=block_r,
                                       interpret=interpret)
        return y[:T].reshape(*lead, m)
    U8, S8, V8 = qt.int_values(Uq), qt.int_values(Sq), qt.int_values(Vq)
    b, p, r = U8.shape
    q = V8.shape[1]
    m, n = b * p, b * q
    xf, lead, T = _flatten_x(x)
    block_t, block_r = _resolve_blocks(block_t, block_r, T, m, n, b, r,
                                       x.dtype, 1, 1, "int8", act)
    r_pad = _round_up(r, block_r)
    U8, S8, V8 = (_pad_last(a, r_pad) for a in (U8, S8, V8))
    if act == "int8":
        xq, sx = _quantize_pad_x(xf, T, block_t)
        y = blast_matmul_w8a8_pallas(xq, sx, U8, S8, V8, su, ss, sv,
                                     block_t=block_t, block_r=block_r,
                                     interpret=interpret, out_dtype=x.dtype)
    else:
        xf, _ = _pad_t(xf, T, block_t)
        y = blast_matmul_q_pallas(xf, U8, S8, V8, su, ss, sv, block_t=block_t,
                                  block_r=block_r, interpret=interpret)
    return y[:T].reshape(*lead, m)


@functools.partial(jax.jit, static_argnames=("block_t", "block_r",
                                             "interpret", "use_pallas", "act"))
def blast_matmul_grouped_q(
    x: jax.Array,
    U8: jax.Array,
    S8: jax.Array,
    V8: jax.Array,
    su: jax.Array,
    ss: jax.Array,
    sv: jax.Array,
    *,
    block_t: int | None = None,
    block_r: int | None = None,
    interpret: bool | None = None,
    use_pallas: bool = True,
    act: str = "none",
) -> jax.Array:
    """Grouped int8-factor BLAST matmul over one shared input.

    x (..., n); U8 (G,b,p,r), S8 (G,b,b,r), V8 (G,b,q,r) int8 codes;
    su/sv (G,b), ss (G,b,b) float scales → (G, ..., m), one launch.
    ``act="int8"`` quantizes x per token once for the whole bundle and runs
    the grouped W8A8 kernel.
    """
    G, b, p, r = U8.shape
    q = V8.shape[2]
    m, n = b * p, b * q
    if not use_pallas:
        if act == "int8":
            xf, lead, T = _flatten_x(x)
            xq, sx = qt.quantize_act(xf)
            y = ref.blast_matmul_grouped_a8_ref(xq, sx, U8, S8, V8,
                                                su, ss, sv)
            return y.reshape(G, *lead, m).astype(x.dtype)
        return ref.blast_matmul_grouped_q_ref(x, U8, S8, V8, su, ss, sv)
    interpret = (not _on_tpu()) if interpret is None else interpret
    xf, lead, T = _flatten_x(x)
    block_t, block_r = _resolve_blocks(block_t, block_r, T, m, n, b, r,
                                       x.dtype, 1, G, "int8", act)
    r_pad = _round_up(r, block_r)
    U8, S8, V8 = (_pad_last(a, r_pad) for a in (U8, S8, V8))
    if act == "int8":
        xq, sx = _quantize_pad_x(xf, T, block_t)
        y = blast_matmul_grouped_w8a8_pallas(xq, sx, U8, S8, V8, su, ss, sv,
                                             block_t=block_t, block_r=block_r,
                                             interpret=interpret,
                                             out_dtype=x.dtype)
    else:
        xf, _ = _pad_t(xf, T, block_t)
        y = blast_matmul_grouped_q_pallas(xf, U8, S8, V8, su, ss, sv,
                                          block_t=block_t, block_r=block_r,
                                          interpret=interpret)
    return y[:, :T].reshape(G, *lead, m)


@functools.partial(jax.jit, static_argnames=("block_t", "block_r",
                                             "interpret", "use_pallas", "act"))
def blast_matmul_grouped_q4(
    x: jax.Array,
    Up: jax.Array,
    Sp: jax.Array,
    Vp: jax.Array,
    su: jax.Array,
    ss: jax.Array,
    sv: jax.Array,
    *,
    block_t: int | None = None,
    block_r: int | None = None,
    interpret: bool | None = None,
    use_pallas: bool = True,
    act: str = "none",
) -> jax.Array:
    """Grouped *nibble-packed* int4 BLAST matmul over one shared input —
    the launch-count hole closer: all-int4 bundles used to fall back to G
    per-member ``blast_matmul_q`` calls.

    x (..., n); Up (G,b,p,r/2), Sp (G,b,b,r/2), Vp (G,b,q,r/2) uint8 nibble
    pairs (packed along r, ``quant/qarray.py`` layout — they stay packed
    into VMEM); su/sv (G,b), ss (G,b,b) float scales → (G, ..., m), one
    launch.  ``act="int8"`` adds per-token activation codes → grouped W4A8.
    """
    G, b, p, r2 = Up.shape
    q = Vp.shape[2]
    r = 2 * r2
    m, n = b * p, b * q
    if not use_pallas:
        U8, S8, V8 = (qt.unpack_int4_planes(a) for a in (Up, Sp, Vp))
        if act == "int8":
            xf, lead, T = _flatten_x(x)
            xq, sx = qt.quantize_act(xf)
            y = ref.blast_matmul_grouped_a8_ref(xq, sx, U8, S8, V8,
                                                su, ss, sv)
            return y.reshape(G, *lead, m).astype(x.dtype)
        return ref.blast_matmul_grouped_q_ref(x, U8, S8, V8, su, ss, sv)
    interpret = (not _on_tpu()) if interpret is None else interpret
    xf, lead, T = _flatten_x(x)
    block_t, block_r = _resolve_blocks(block_t, block_r, T, m, n, b, r,
                                       x.dtype, 0.5, G, "int4", act)
    r_pad = _round_up(r, block_r)
    Up, Sp, Vp = (_pad_last(a, r_pad // 2) for a in (Up, Sp, Vp))
    if act == "int8":
        xq, sx = _quantize_pad_x(xf, T, block_t)
        y = blast_matmul_grouped_w4a8_pallas(xq, sx, Up, Sp, Vp, su, ss, sv,
                                             block_t=block_t, block_r=block_r,
                                             interpret=interpret,
                                             out_dtype=x.dtype)
    else:
        xf, _ = _pad_t(xf, T, block_t)
        y = blast_matmul_grouped_q4_pallas(xf, Up, Sp, Vp, su, ss, sv,
                                           block_t=block_t, block_r=block_r,
                                           interpret=interpret)
    return y[:, :T].reshape(G, *lead, m)


# ---------------------------------------------------------------------------
# Tensor-parallel grouped wrappers: the BLAST rank contraction is a sum, so
# sharding the trailing rank axis of U/S/V across the mesh "model" axis makes
# stages 1-2 fully local and costs ONE stage-3 psum per bundle (the Megatron
# row-parallel pattern, DESIGN.md §3).  Each device runs its own grouped
# kernel launch on its local rank shard — one launch per bundle per shard —
# and ``_resolve_blocks`` / the autotune cache see the *local* shapes
# (r/tp), so per-shard tilings tune independently of the 1-device ones.
#
# Exactness: the per-block scales su/ss/sv are constant along r, so scaling
# each shard's partial output and summing equals scaling the full sum.  For
# the packed-int4 variant the byte axis is sharded instead (r2 = r/2 bytes);
# tp must divide r2, which keeps nibble pairs on one shard, and the plane
# unpack is a local rank permutation — invariant under the contraction.
# ---------------------------------------------------------------------------


def _grouped_tp(inner, x, factors, scales, *, mesh, axis, kwargs):
    tp = mesh.shape[axis]
    r_stored = factors[0].shape[-1]
    if tp == 1 or r_stored % tp != 0:
        # not rank-shardable on this mesh → single grouped launch, replicated
        return inner(x, *factors, *scales, **kwargs)
    fspec = P(None, None, None, axis)
    rep = P()

    def body(xl, Ul, Sl, Vl, *sc):
        y = inner(xl, Ul, Sl, Vl, *sc, **kwargs)
        return jax.lax.psum(y, axis)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(rep, fspec, fspec, fspec) + (rep,) * len(scales),
        out_specs=rep, check_vma=False)(x, *factors, *scales)


def blast_matmul_grouped_tp(x, U, S, V, *, mesh, axis="model",
                            block_t=None, block_r=None, interpret=None,
                            use_pallas=True):
    """``blast_matmul_grouped`` under shard_map: float factors rank-sharded
    over ``mesh.shape[axis]`` devices, one grouped launch per shard plus one
    stage-3 psum.  Falls back to the replicated single launch when the rank
    is not divisible by the axis size."""
    return _grouped_tp(blast_matmul_grouped, x, (U, S, V), (),
                       mesh=mesh, axis=axis,
                       kwargs=dict(block_t=block_t, block_r=block_r,
                                   interpret=interpret,
                                   use_pallas=use_pallas))


def blast_matmul_grouped_q_tp(x, U8, S8, V8, su, ss, sv, *, mesh,
                              axis="model", block_t=None, block_r=None,
                              interpret=None, use_pallas=True, act="none"):
    """``blast_matmul_grouped_q`` under shard_map: int8 codes rank-sharded,
    per-block scales replicated (they are constant along r).  With
    ``act="int8"`` every shard quantizes the replicated x identically, so
    the W8A8 path stays bit-identical to the 1-device launch."""
    return _grouped_tp(blast_matmul_grouped_q, x, (U8, S8, V8), (su, ss, sv),
                       mesh=mesh, axis=axis,
                       kwargs=dict(block_t=block_t, block_r=block_r,
                                   interpret=interpret, use_pallas=use_pallas,
                                   act=act))


def blast_matmul_grouped_q4_tp(x, Up, Sp, Vp, su, ss, sv, *, mesh,
                               axis="model", block_t=None, block_r=None,
                               interpret=None, use_pallas=True, act="none"):
    """``blast_matmul_grouped_q4`` under shard_map: the nibble-packed byte
    axis (r/2) is sharded, so factors stay packed per shard and unpack
    in-register as usual; tp must divide the byte count (else the replicated
    fallback runs)."""
    return _grouped_tp(blast_matmul_grouped_q4, x, (Up, Sp, Vp),
                       (su, ss, sv), mesh=mesh, axis=axis,
                       kwargs=dict(block_t=block_t, block_r=block_r,
                                   interpret=interpret, use_pallas=use_pallas,
                                   act=act))


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_kv", "interpret", "use_pallas"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jax.Array:
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D) → (B, Hq, T, D)."""
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, Hq, T, D = q.shape
    S_len = k.shape[2]
    block_q = min(block_q, _round_up(T, 8))
    block_kv = min(block_kv, _round_up(S_len, 8))
    T_pad = _round_up(T, block_q)
    S_pad = _round_up(S_len, block_kv)
    if T_pad != T:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, T_pad - T), (0, 0)))
    if S_pad != S_len:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, S_pad - S_len), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, S_pad - S_len), (0, 0)))
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        kv_len=S_len, block_q=block_q, block_kv=block_kv, interpret=interpret)
    return out[:, :, :T, :]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret", "use_pallas"))
def flash_attention_prefill(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_offsets: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jax.Array:
    """Chunked-prefill attention at per-sequence offsets (continuous batching).

    q: (B, Hq, C, D) — one C-token chunk per row; k, v: (B, Hkv, S, D) — the
    positional KV cache (chunk keys already written at their absolute slots);
    q_offsets: (B,) int32 first-token position per row.  The causal mask is
    shifted by each row's offset — the C×max_len prefill step of the serving
    engine's mixed batches.
    """
    if not use_pallas:
        return ref.attention_prefill_ref(q, k, v, q_offsets, causal=causal,
                                         window=window)
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, Hq, T, D = q.shape
    S_len = k.shape[2]
    block_q = min(block_q, _round_up(T, 8))
    block_kv = min(block_kv, _round_up(S_len, 8))
    T_pad = _round_up(T, block_q)
    S_pad = _round_up(S_len, block_kv)
    if T_pad != T:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, T_pad - T), (0, 0)))
    if S_pad != S_len:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, S_pad - S_len), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, S_pad - S_len), (0, 0)))
    out = flash_attention_prefill_pallas(
        q, k, v, q_offsets, causal=causal, window=window, kv_len=S_len,
        block_q=block_q, block_kv=block_kv, interpret=interpret)
    return out[:, :, :T, :]
