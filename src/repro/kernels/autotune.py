"""Empirical tiling autotuner for the fused BLAST kernels.

``ops.py`` picks ``(block_t, block_r)`` with a VMEM-budget heuristic
(``pick_blast_blocks``).  The heuristic is shape-blind about *throughput*:
it returns the largest resident-set-feasible tiles, which is right for big
prefill GEMMs but measurably wrong for skinny decode calls where grid
overhead and r-tile granularity dominate.  This module times the real
candidate configs per ``(T, m, n, b, r, G, dtype, kind, backend)`` key and
persists the winners, so repeated engine builds and serving runs skip
straight to the measured-best tiling.

Contract
--------
* Disabled by default: ``ops`` falls back to ``pick_blast_blocks`` —
  enabling/disabling never changes numerics, only tile choices.
* ``enable(path)`` installs a process-wide ``TuningCache`` backed by a JSON
  file (see below); ``lookup`` is a trace-time dict read, so tuned tiles
  bake into jitted programs compiled after enabling.
* ``tune_blast`` times each candidate with compiled real kernels
  (best-of-``reps`` wall time after ``block_until_ready``) and records the
  winner; re-tuning an already-cached key is a no-op unless ``force``.

Cache file format (version 2)::

    {"version": 2,
     "entries": {"T8.m128.n64.b4.r24.G1.float32.int8.cpu.a8": [8, 32], ...}}

Keys encode the call signature (logical T before padding, full factor
shape, group size G, input dtype, factor kind float/int8/int4, JAX
backend, activation storage none/int8 — W8A8/W4A8 calls tile differently
from their float-activation twins, so they tune independently); values are
``[block_t, block_r]``.  Unknown versions are ignored (treated as empty) so
stale caches can never poison a run — version 1 files predate the
activation-storage key component and are exactly the mis-hit the bump
guards against.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

_VERSION = 2
_DEFAULT_PATH = os.path.join(".", ".autotune", "blast_tiling.json")


@dataclasses.dataclass(frozen=True)
class Key:
    """Identity of one tiling decision (all static trace-time ints/strs)."""

    T: int
    m: int
    n: int
    b: int
    r: int
    G: int = 1
    dtype: str = "float32"
    kind: str = "float"     # float | int8 | int4 (factor storage)
    backend: str = "cpu"
    act: str = "none"       # none | int8 (activation storage: A8 paths)

    def encode(self) -> str:
        a = {"none": "anone", "int8": "a8"}.get(self.act, f"a{self.act}")
        return (f"T{self.T}.m{self.m}.n{self.n}.b{self.b}.r{self.r}"
                f".G{self.G}.{self.dtype}.{self.kind}.{self.backend}.{a}")


class TuningCache:
    """On-disk (JSON) block-size cache with in-memory mirror."""

    def __init__(self, path: str | None = None):
        self.path = path or _DEFAULT_PATH
        self.entries: dict[str, tuple[int, int]] = {}
        self.load()

    def load(self) -> None:
        try:
            with open(self.path) as f:
                raw = json.load(f)
        except (OSError, ValueError):
            return
        if not isinstance(raw, dict) or raw.get("version") != _VERSION:
            return
        for k, v in raw.get("entries", {}).items():
            if (isinstance(v, (list, tuple)) and len(v) == 2
                    and all(isinstance(x, int) and x > 0 for x in v)):
                self.entries[k] = (v[0], v[1])

    def save(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": _VERSION,
                       "entries": {k: list(v)
                                   for k, v in sorted(self.entries.items())}},
                      f, indent=0, sort_keys=True)
        os.replace(tmp, self.path)

    def get(self, key: Key) -> tuple[int, int] | None:
        return self.entries.get(key.encode())

    def put(self, key: Key, blocks: tuple[int, int]) -> None:
        self.entries[key.encode()] = (int(blocks[0]), int(blocks[1]))


# -- module state (consulted by kernels/ops.py at trace time) ----------------

_STATE: dict = {"cache": None}


def enable(path: str | None = None) -> TuningCache:
    """Install (or reuse) the process-wide cache.  Idempotent per path."""
    cache = _STATE["cache"]
    if cache is None or (path is not None and cache.path != path):
        cache = TuningCache(path)
        _STATE["cache"] = cache
    return cache


def disable() -> None:
    _STATE["cache"] = None


def enabled() -> bool:
    return _STATE["cache"] is not None


def cache() -> TuningCache | None:
    """The installed process-wide cache (None while disabled)."""
    return _STATE["cache"]


def lookup(key: Key) -> tuple[int, int] | None:
    """Tuned blocks for ``key``, or None (→ caller uses the heuristic).
    Trace-time read: runs inside jit tracing, so results must stay stable
    for the life of the process unless the user re-tunes before a retrace."""
    cache = _STATE["cache"]
    return None if cache is None else cache.get(key)


def save() -> None:
    if _STATE["cache"] is not None:
        _STATE["cache"].save()


# -- candidate generation & timing -------------------------------------------


def candidates(T: int, m: int, n: int, b: int, r: int,
               bytes_per_el: int = 4,
               factor_bytes: float | None = None) -> list[tuple[int, int]]:
    """VMEM-feasible (block_t, block_r) configs worth timing.

    The sweep is the heuristic's own search lattice (block_t halvings ×
    {128, 64, 32} r-tiles) clamped to the call's actual (rounded-up) T and
    r — a handful of configs, always including the heuristic's pick.
    """
    from repro.kernels import ops  # local: ops imports this module

    t_cap = min(128, ops._round_up(T, 8))
    r_cap = min(128, ops._round_up(r, 8))
    fb = bytes_per_el if factor_bytes is None else factor_bytes
    p, q = m // b, n // b
    out: list[tuple[int, int]] = []
    bt = t_cap
    while bt >= 8:
        for br in (128, 64, 32):
            br = min(br, r_cap)
            resident = (
                bt * n * bytes_per_el
                + b * bt * br * 4
                + bt * m * 4
                + int((p * br + b * b * br + b * q * br) * fb)
            )
            if resident <= ops._VMEM_BUDGET and (bt, br) not in out:
                out.append((bt, br))
        if bt == 8:
            break
        bt = max(bt // 2, 8)
    heur = ops.pick_blast_blocks(T, m, n, b, r, bytes_per_el, factor_bytes)
    heur = (min(heur[0], t_cap), min(heur[1], r_cap))
    if heur not in out:
        out.append(heur)
    return out


def _time_call(fn, reps: int = 3) -> float:
    import jax

    jax.block_until_ready(fn())  # compile + warm outside the timed region
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def tune_blast(T: int, m: int, n: int, b: int, r: int, *,
               G: int = 1, dtype=None, kind: str = "float",
               act: str = "none", reps: int = 3, force: bool = False,
               seed: int = 0) -> tuple[int, int]:
    """Measure the candidate tilings for one BLAST call shape and cache the
    winner.  Operands are synthetic (timing only).  Returns the chosen
    ``(block_t, block_r)``; with tuning disabled, returns the heuristic
    pick without timing or caching.  ``act="int8"`` times the W8A8/W4A8
    integer-contraction path (requires ``kind`` int8/int4).
    """
    import jax
    import jax.numpy as jnp

    from repro import quant as qt
    from repro.kernels import ops

    dtype = jnp.dtype(jnp.float32 if dtype is None else dtype)
    if act != "none" and kind == "float":
        raise ValueError("act='int8' requires quantized factors "
                         "(kind int8/int4)")
    key = Key(T=T, m=m, n=n, b=b, r=r, G=G, dtype=dtype.name, kind=kind,
              backend=jax.default_backend(), act=act)
    fb = {"float": dtype.itemsize, "int8": 1, "int4": 0.5}[kind]
    cache = _STATE["cache"]
    if cache is None:
        return ops.pick_blast_blocks(T, m, n, b, r, dtype.itemsize, fb)
    if not force:
        hit = cache.get(key)
        if hit is not None:
            return hit

    rng = jax.random.PRNGKey(seed)
    kx, kf = jax.random.split(rng)
    p, q = m // b, n // b
    x = jax.random.normal(kx, (T, n), dtype=dtype)
    lead = (G,) if G > 1 else ()
    ku, ks, kv = jax.random.split(kf, 3)
    U = jax.random.normal(ku, (*lead, b, p, r), dtype=dtype)
    S = jax.random.normal(ks, (*lead, b, b, r), dtype=dtype)
    V = jax.random.normal(kv, (*lead, b, q, r), dtype=dtype)
    if kind != "float":
        bits = 8 if kind == "int8" else 4
        uv_axes = (len(lead) + 1, len(lead) + 2)   # per U_i / V_j block
        Uq = qt.quantize(U, bits=bits, block_axes=uv_axes)
        Sq = qt.quantize(S, bits=bits, block_axes=(len(lead) + 2,))
        Vq = qt.quantize(V, bits=bits, block_axes=uv_axes)

    def run(bt: int, br: int):
        if kind == "float":
            if G > 1:
                return ops.blast_matmul_grouped(x, U, S, V,
                                                block_t=bt, block_r=br)
            return ops.blast_matmul(x, U, S, V, block_t=bt, block_r=br)
        if G > 1:
            su = Uq.scale.reshape(G, b)
            ss = Sq.scale.reshape(G, b, b)
            sv = Vq.scale.reshape(G, b)
            if kind == "int4":
                return ops.blast_matmul_grouped_q4(
                    x, Uq.q, Sq.q, Vq.q, su, ss, sv,
                    block_t=bt, block_r=br, act=act)
            return ops.blast_matmul_grouped_q(
                x, qt.int_values(Uq), qt.int_values(Sq), qt.int_values(Vq),
                su, ss, sv, block_t=bt, block_r=br, act=act)
        return ops.blast_matmul_q(x, Uq, Sq, Vq, block_t=bt, block_r=br,
                                  act=act)

    best, best_t = None, float("inf")
    for bt, br in candidates(T, m, n, b, r, dtype.itemsize, fb):
        try:
            dt = _time_call(lambda: run(bt, br), reps=reps)
        except Exception:  # infeasible tiling on this backend: skip
            continue
        if dt < best_t:
            best, best_t = (bt, br), dt
    if best is None:  # every candidate failed — keep the heuristic
        return ops.pick_blast_blocks(T, m, n, b, r, dtype.itemsize, fb)
    cache.put(key, best)
    return best
