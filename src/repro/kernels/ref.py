"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def blast_matmul_ref(x: jax.Array, U: jax.Array, S: jax.Array, V: jax.Array) -> jax.Array:
    """Alg. 1 reference: x (..., n) → (..., m); U (b,p,r), S (b,b,r), V (b,q,r)."""
    b, q, r = V.shape
    p = U.shape[1]
    lead = x.shape[:-1]
    xb = x.reshape(*lead, b, q).astype(jnp.float32)
    z = jnp.einsum("...jq,jqr->...jr", xb, V.astype(jnp.float32))
    w = jnp.einsum("...jr,ijr->...ir", z, S.astype(jnp.float32))
    y = jnp.einsum("...ir,ipr->...ip", w, U.astype(jnp.float32))
    return y.reshape(*lead, b * p).astype(x.dtype)


def blast_matmul_q_ref(x: jax.Array, U: jax.Array, S: jax.Array, V: jax.Array,
                       su: jax.Array, ss: jax.Array, sv: jax.Array) -> jax.Array:
    """int8-factor oracle: dequantize U/S/V with the per-block scales
    (su (b,), ss (b,b), sv (b,)) and run the Alg. 1 reference."""
    Uf = U.astype(jnp.float32) * su.astype(jnp.float32)[:, None, None]
    Sf = S.astype(jnp.float32) * ss.astype(jnp.float32)[:, :, None]
    Vf = V.astype(jnp.float32) * sv.astype(jnp.float32)[:, None, None]
    return blast_matmul_ref(x, Uf, Sf, Vf)


def blast_matmul_grouped_ref(x: jax.Array, U: jax.Array, S: jax.Array,
                             V: jax.Array) -> jax.Array:
    """Grouped oracle == the per-projection loop: x (..., n) shared;
    U (G,b,p,r), S (G,b,b,r), V (G,b,q,r) → y (G, ..., m)."""
    return jnp.stack([blast_matmul_ref(x, U[g], S[g], V[g])
                      for g in range(U.shape[0])])


def blast_matmul_grouped_q_ref(x: jax.Array, U: jax.Array, S: jax.Array,
                               V: jax.Array, su: jax.Array, ss: jax.Array,
                               sv: jax.Array) -> jax.Array:
    """Grouped int8-factor oracle: per-projection loop over the G sets.
    Codes (G,b,·,r); scales su/sv (G,b), ss (G,b,b) → y (G, ..., m)."""
    return jnp.stack([
        blast_matmul_q_ref(x, U[g], S[g], V[g], su[g], ss[g], sv[g])
        for g in range(U.shape[0])])


def blast_matmul_a8_ref(xq: jax.Array, sx: jax.Array, U: jax.Array,
                        S: jax.Array, V: jax.Array, su: jax.Array,
                        ss: jax.Array, sv: jax.Array) -> jax.Array:
    """Integer-exact W8A8/W4A8 oracle mirroring the kernel's fusion order:
    stage 1 contracts int8 activation codes against int8 factor codes in
    int32, then dequantizes ONCE with ``sx · sv_j``; stages 2–3 run on the
    fp32 ``z`` exactly like the weight-only path.

    xq (..., n) int8, sx (..., 1) fp32 (``quantize_act`` layout); U/S/V are
    int8 codes (b,·,r) — callers unpack int4 to codes first (plane or
    logical order, both exact).  Returns fp32 (..., m).
    """
    b, q, r = V.shape
    p = U.shape[1]
    lead = xq.shape[:-1]
    xb = xq.reshape(*lead, b, q)
    z32 = jnp.einsum("...jq,jqr->...jr", xb, V,
                     preferred_element_type=jnp.int32)
    z = (z32.astype(jnp.float32) * sx.astype(jnp.float32)[..., None]
         * sv.astype(jnp.float32)[:, None])
    Sf = S.astype(jnp.float32) * ss.astype(jnp.float32)[:, :, None]
    w = jnp.einsum("...jr,ijr->...ir", z, Sf)
    y = jnp.einsum("...ir,ipr->...ip", w, U.astype(jnp.float32))
    y = y * su.astype(jnp.float32)[:, None]
    return y.reshape(*lead, b * p)


def blast_matmul_grouped_a8_ref(xq: jax.Array, sx: jax.Array, U: jax.Array,
                                S: jax.Array, V: jax.Array, su: jax.Array,
                                ss: jax.Array, sv: jax.Array) -> jax.Array:
    """Grouped integer-activation oracle: per-projection loop over G sets of
    int8 codes sharing one set of activation codes → y (G, ..., m)."""
    return jnp.stack([
        blast_matmul_a8_ref(xq, sx, U[g], S[g], V[g], su[g], ss[g], sv[g])
        for g in range(U.shape[0])])


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Full-softmax reference attention with GQA + optional sliding window.

    q: (B, Hq, T, D); k, v: (B, Hkv, S, D).  Query position i attends to key
    position j iff  j ≤ i+q_offset  (causal) and  j > i+q_offset-window.
    """
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, T, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgtd,bhsd->bhgts", qf, kf) / jnp.sqrt(D)
    S_len = k.shape[2]
    qi = jnp.arange(T)[:, None] + q_offset
    kj = jnp.arange(S_len)[None, :]
    mask = jnp.ones((T, S_len), dtype=bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhgts,bhsd->bhgtd", probs, vf)
    return out.reshape(B, Hq, T, D).astype(q.dtype)


def attention_prefill_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_offsets: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    kv_len: int | None = None,
) -> jax.Array:
    """Oracle for the prefill-at-offset kernel: per-batch shifted causal mask.

    q: (B, Hq, C, D); k, v: (B, Hkv, S, D); q_offsets: (B,).  Query (b, t)
    at absolute position ``q_offsets[b] + t`` attends to key j iff
    ``j <= q_offsets[b] + t`` (and within the sliding window, if any).
    """
    B, Hq, T, D = q.shape
    Hkv, S_len = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kv_len = S_len if kv_len is None else kv_len
    qf = q.reshape(B, Hkv, G, T, D).astype(jnp.float32)
    scores = jnp.einsum("bhgtd,bhsd->bhgts", qf, k.astype(jnp.float32)) / jnp.sqrt(D)
    qi = q_offsets.astype(jnp.int32)[:, None, None] + jnp.arange(T)[None, :, None]
    kj = jnp.arange(S_len)[None, None, :]
    mask = jnp.broadcast_to(kj < kv_len, (B, T, S_len))
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    scores = jnp.where(mask[:, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhgts,bhsd->bhgtd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, T, D).astype(q.dtype)
