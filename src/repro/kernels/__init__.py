"""Pallas TPU kernels for the framework's compute hot-spots.

- ``blast_matmul``      fused 3-stage BLAST product (paper Alg. 1, §2)
- ``flash_attention``   causal / sliding-window / GQA online-softmax attention
- ``ref``               pure-jnp oracles (the correctness contract)
- ``ops``               jit'd wrappers: padding, block sizing, CPU interpret

Decode note: at T <= block_t the fused BLAST kernel runs a single T-tile, so
every factor (U, S, V) streams from HBM exactly once -- already
bandwidth-optimal for the paper's Table-4 matvec regime (the roofline term
is the (m+n+b^2)*r parameter bytes); no separate decode kernel is needed.
"""

from repro.kernels.ops import blast_matmul, flash_attention  # noqa: F401
