"""Pallas TPU kernels for the framework's compute hot-spots.

- ``blast_matmul``          fused 3-stage BLAST product (paper Alg. 1, §2)
- ``blast_matmul_grouped``  G congruent factor sets, one shared x, one launch
- ``blast_matmul_q``        int8 / nibble-packed-int4 factor variants
- ``flash_attention``       causal / sliding-window / GQA online-softmax attn
- ``autotune``              measured (block_t, block_r) cache per call shape
- ``ref``                   pure-jnp oracles (the correctness contract)
- ``ops``                   jit'd wrappers: padding, block sizing, interpret

Decode note: at T <= block_t the fused BLAST kernel runs a single T-tile, so
every factor (U, S, V) streams from HBM exactly once -- bandwidth-optimal
for the paper's Table-4 matvec regime (the roofline term is the
(m+n+b^2)*r parameter bytes).  What decode *launches* pay for is the
per-projection dispatch + x-tile overhead; the grouped kernels amortize
both across every shape-congruent projection bundle of a layer (see
``README.md`` in this package for the tiling/grouping contract).
"""

from repro.kernels.ops import (blast_matmul, blast_matmul_grouped,  # noqa: F401
                               blast_matmul_grouped_q, blast_matmul_q,
                               flash_attention)
