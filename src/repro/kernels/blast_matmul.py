"""Fused BLAST matmul Pallas TPU kernels (paper Alg. 1, TPU-native).

GPU version (paper App. A): three separate ``torch.bmm``/broadcast kernels,
materializing ``Z = (b, T, r)`` and ``W = (b, T, r)`` in HBM between calls.

TPU adaptation: one fused kernel.  Grid = ``(T_tiles, r_tiles, b_i)``:

  * at ``i == 0`` the stage-1 products ``z_j = x_j @ V_j[:, rt]`` for *all*
    input blocks j are computed into a VMEM scratch ``(b, T_t, r_t)`` — once
    per (T, r) tile, amortized over all b output blocks;
  * each i does the VPU coupling reduce ``w_i = Σ_j s_ij ⊙ z_j`` and the MXU
    projection ``y_i += w_i @ U_iᵀ``, accumulated in a fp32 VMEM scratch
    ``(T_t, m)`` that is flushed to HBM once per T tile.

Z and W therefore never touch HBM; the only HBM traffic is X, U/S/V (once
per T tile) and Y (once).  Block shapes are chosen in ``ops.py`` so the
resident set (x-tile + z-scratch + y-accumulator + factor tiles) fits a
16 MB v5e VMEM, with MXU-aligned (multiple-of-128) r/T tiles when possible.

Variants (all share the ``_stages`` scaffold — the three compute stages,
accumulator init and flush are written once, parameterized by factor
loaders / per-stage dequant scalers):

  * ``blast_matmul_pallas``             float factors
  * ``blast_matmul_q_pallas``           int8-code factors, per-block scales
  * ``blast_matmul_q4_pallas``          nibble-packed int4 factors (packed in
                                        HBM *and* VMEM; unpacked in-register)
  * ``blast_matmul_grouped_pallas``     G stacked factor sets, one shared x
  * ``blast_matmul_grouped_q_pallas``   grouped + int8 factors
  * ``blast_matmul_grouped_q4_pallas``  grouped + packed int4 factors
  * ``blast_matmul_w8a8_pallas``        int8 factors × int8 activation codes
  * ``blast_matmul_w4a8_pallas``        packed int4 factors × int8 act codes
  * ``blast_matmul_grouped_w8a8_pallas`` / ``…_w4a8_pallas``  grouped ditto

Integer activations (the ``w8a8``/``w4a8`` variants): ``x`` arrives as int8
per-token codes with a fp32 per-row scale ``sx (T, 1)``.  Stage 1 — the only
stage that contracts activations — runs as a true int8×int8 MXU dot
accumulating in int32 (``preferred_element_type=jnp.int32``); the fused
dequant multiplies the int32 tile by the *product* ``sx · sv_j`` once, after
the dot.  The int32 stage-1 result is exact (|codes| ≤ 127, so q·127² per
row fits int32 for any realistic block width), so the only error the A8
path adds over the weight-only kernels is the activation rounding itself —
stages 2–3 then run on the already-dequantized fp32 ``z`` exactly as in the
weight-only kernels, keeping one shared ``_stages`` body and avoiding the
int32 overflow / requantization error a fully-integer stage 2 would incur
(``z`` entries reach q·16129 before coupling scales are applied).

Grouped kernels add a leading grid dimension over G: the x tile's block
index is independent of ``g``, so Pallas keeps it resident in VMEM across
the whole group — G shape-congruent projections (qkv bundles, gate+up,
MLA a-projections) cost one kernel launch and one x-tile load instead of G.

int4 layout: factors are nibble-packed along r (two codes per byte, the
``quant/qarray.py`` interleaved convention — byte k of a tile holds logical
ranks 2k and 2k+1).  The kernel unpacks each VMEM tile in-register into
*plane order* ``[even ranks | odd ranks]`` without re-interleaving: the
BLAST contraction reduces over r in stages 2–3 only, so any r-permutation
applied consistently to U, S, V and the derived z is exact.  Padding r to a
block multiple appends zero bytes (zero codes), which contribute nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unpack_nibbles(packed: jax.Array) -> jax.Array:
    """uint8 nibble pairs (..., P) → int32 codes (..., 2P) in plane order
    ``[low nibbles | high nibbles]`` (branch-free sign extension)."""
    v = packed.astype(jnp.int32)
    lo = v & 0xF
    hi = (v >> 4) & 0xF
    lo = lo - ((lo & 0x8) << 1)
    hi = hi - ((hi & 0x8) << 1)
    return jnp.concatenate([lo, hi], axis=-1)


# ---------------------------------------------------------------------------
# Shared kernel scaffold.
# ---------------------------------------------------------------------------


def _stages(x_ref, out_ref, z_scr, y_scr, *, b, n_r_tiles, rt_axis,
            load_v, load_s, load_u, scale_z, scale_y, acc1=jnp.float32):
    """The three Alg.-1 stages + accumulator init/flush, shared by every
    kernel variant.

    ``rt_axis`` is the grid axis of the r tile (the block index ``i`` rides
    on ``rt_axis + 1``); grouped kernels shift both right by one.  Factor
    access is abstracted: ``load_v(j, dtype)`` / ``load_u()`` / ``load_s(i)``
    return MXU/VPU-ready tiles (quantized variants cast codes in-register),
    ``scale_z(z_j, j)`` / ``scale_y(y_i, i)`` apply the per-block dequant
    scales on the stage *outputs*.  ``acc1`` is the stage-1 accumulator
    dtype: ``jnp.int32`` for the integer-activation kernels (int8×int8 MXU
    dot on codes; ``scale_z`` then dequantizes the int32 tile), fp32
    otherwise.
    """
    rt = pl.program_id(rt_axis)
    i = pl.program_id(rt_axis + 1)
    q = x_ref.shape[1] // b

    # ---- stage 1 (once per (T, r) tile): z_j = x_j @ V_j
    @pl.when(i == 0)
    def _compute_z():
        x = x_ref[...]
        for j in range(b):  # b is static and small (≤16): unrolled
            xj = x[:, j * q:(j + 1) * q]
            zj = jax.lax.dot_general(
                xj, load_v(j, x.dtype), (((1,), (0,)), ((), ())),
                preferred_element_type=acc1,
            )
            z_scr[j] = scale_z(zj, j)

    @pl.when((rt == 0) & (i == 0))
    def _init_acc():
        y_scr[...] = jnp.zeros_like(y_scr)

    # ---- stage 2 (VPU): w_i = Σ_j s_ij ⊙ z_j
    s_i = load_s(i)                                       # (b, r_t) fp32
    w = jnp.sum(s_i[:, None, :] * z_scr[...], axis=0)     # (T_t, r_t)

    # ---- stage 3 (MXU): y_i += w @ U_iᵀ, accumulated over r tiles
    u_i = load_u()                                        # (p, r_t)
    y_part = jax.lax.dot_general(
        w, u_i, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    p = u_i.shape[0]
    col = i * p
    y_scr[:, pl.ds(col, p)] = y_scr[:, pl.ds(col, p)] + scale_y(y_part, i)

    # ---- flush once per T tile
    @pl.when((rt == n_r_tiles - 1) & (i == b - 1))
    def _flush():
        out_ref[...] = y_scr[...].reshape(out_ref.shape).astype(out_ref.dtype)


def _float_loaders(u_ref, s_ref, v_ref):
    """Factor accessors for float kernels; handles the grouped variants'
    extra leading unit block dim by indexing it away."""
    gl = s_ref.ndim - 3  # 0 ungrouped, 1 grouped
    s3 = s_ref[0] if gl else s_ref[...]
    return dict(
        load_v=lambda j, dt: v_ref[(0,) * gl + (j,)],
        load_s=lambda i: jax.lax.dynamic_index_in_dim(
            s3, i, 0, keepdims=False).astype(jnp.float32),
        load_u=lambda: u_ref[(0,) * (u_ref.ndim - 2)],
        scale_z=lambda z, j: z,
        scale_y=lambda y, i: y,
    )


def _quant_loaders(u_ref, s_ref, v_ref, su_ref, ss_ref, sv_ref, *,
                   g=None, packed=False):
    """Factor accessors for the int8/int4 kernels: U/S/V tiles arrive in
    VMEM as integer codes (the whole point — half/quarter the HBM traffic),
    are cast (int4: unpacked) in-register for the MXU/VPU ops, and each
    stage's per-block scale multiplies the stage *output* — quantized
    factors never round-trip through HBM as floats.

    ``su``/``sv`` are scalar-prefetched into SMEM (scalar reads per block
    index); ``ss`` rides as a tiny fp32 VMEM operand ``(b, b, 1)`` so the
    per-row read ``ss[i]`` is a single vectorized load, not b scalar picks.
    ``g`` indexes the grouped variants' leading factor-set axis.
    """
    gl = s_ref.ndim - 3
    s3 = s_ref[0] if gl else s_ref[...]
    ss3 = ss_ref[0] if ss_ref.ndim == 4 else ss_ref[...]   # (b, b, 1) fp32
    unpack = _unpack_nibbles if packed else (lambda t: t)
    su = (lambda i: su_ref[g, i]) if g is not None else (lambda i: su_ref[i])
    sv = (lambda j: sv_ref[g, j]) if g is not None else (lambda j: sv_ref[j])

    def load_s(i):
        codes = jax.lax.dynamic_index_in_dim(s3, i, 0, keepdims=False)
        ss_i = jax.lax.dynamic_index_in_dim(ss3, i, 0, keepdims=False)
        return unpack(codes).astype(jnp.float32) * ss_i    # (b, r_t)·(b, 1)

    return dict(
        load_v=lambda j, dt: unpack(v_ref[(0,) * gl + (j,)]).astype(dt),
        load_s=load_s,
        load_u=lambda: unpack(
            u_ref[(0,) * (u_ref.ndim - 2)]).astype(jnp.float32),
        scale_z=lambda z, j: z * sv(j),
        scale_y=lambda y, i: y * su(i),
    )


def _quant_act_loaders(u_ref, s_ref, v_ref, su_ref, ss_ref, sv_ref, sx_ref,
                       *, g=None, packed=False):
    """Loaders for the integer-activation (W8A8 / W4A8) kernels.

    ``x_ref`` holds int8 per-token codes, so ``load_v`` hands stage 1 raw
    int8 V codes (int4: nibble-unpacked then narrowed back to int8 — values
    live in [-8, 7]) and the stage-1 dot runs int8×int8 → int32 on the MXU.
    ``scale_z`` fuses the activation and factor dequant into ONE multiply of
    the int32 tile: ``z · (sx ⊗ sv_j)``, with ``sx`` the fp32 per-row
    activation scale tile ``(T_t, 1)``.  U/S stages are unchanged from
    ``_quant_loaders`` — they consume the already-dequantized fp32 ``z``.
    """
    base = _quant_loaders(u_ref, s_ref, v_ref, su_ref, ss_ref, sv_ref,
                          g=g, packed=packed)
    if packed:
        load_v = lambda j, dt: _unpack_nibbles(  # noqa: E731
            v_ref[(0,) * (s_ref.ndim - 3) + (j,)]).astype(jnp.int8)
    else:
        load_v = lambda j, dt: v_ref[(0,) * (s_ref.ndim - 3) + (j,)]  # noqa: E731
    sv = ((lambda j: sv_ref[g, j]) if g is not None
          else (lambda j: sv_ref[j]))
    return dict(
        base,
        load_v=load_v,
        scale_z=lambda z, j: z.astype(jnp.float32) * (sx_ref[...] * sv(j)),
    )


# ---------------------------------------------------------------------------
# Kernel bodies (thin: bind loaders + grid-axis layout, call _stages).
# ---------------------------------------------------------------------------


def _kernel(x_ref, u_ref, s_ref, v_ref, out_ref, z_scr, y_scr, *, b: int,
            n_r_tiles: int):
    _stages(x_ref, out_ref, z_scr, y_scr, b=b, n_r_tiles=n_r_tiles,
            rt_axis=1, **_float_loaders(u_ref, s_ref, v_ref))


def _kernel_grouped(x_ref, u_ref, s_ref, v_ref, out_ref, z_scr, y_scr, *,
                    b: int, n_r_tiles: int):
    _stages(x_ref, out_ref, z_scr, y_scr, b=b, n_r_tiles=n_r_tiles,
            rt_axis=2, **_float_loaders(u_ref, s_ref, v_ref))


def _kernel_q(su_ref, sv_ref, x_ref, u_ref, s_ref, v_ref, ss_ref, out_ref,
              z_scr, y_scr, *, b: int, n_r_tiles: int, packed: bool = False):
    _stages(x_ref, out_ref, z_scr, y_scr, b=b, n_r_tiles=n_r_tiles,
            rt_axis=1, **_quant_loaders(u_ref, s_ref, v_ref,
                                        su_ref, ss_ref, sv_ref,
                                        packed=packed))


def _kernel_grouped_q(su_ref, sv_ref, x_ref, u_ref, s_ref, v_ref, ss_ref,
                      out_ref, z_scr, y_scr, *, b: int, n_r_tiles: int,
                      packed: bool = False):
    g = pl.program_id(0)
    _stages(x_ref, out_ref, z_scr, y_scr, b=b, n_r_tiles=n_r_tiles,
            rt_axis=2, **_quant_loaders(u_ref, s_ref, v_ref,
                                        su_ref, ss_ref, sv_ref, g=g,
                                        packed=packed))


def _kernel_qa(su_ref, sv_ref, x_ref, u_ref, s_ref, v_ref, ss_ref, sx_ref,
               out_ref, z_scr, y_scr, *, b: int, n_r_tiles: int,
               packed: bool = False):
    _stages(x_ref, out_ref, z_scr, y_scr, b=b, n_r_tiles=n_r_tiles,
            rt_axis=1, acc1=jnp.int32,
            **_quant_act_loaders(u_ref, s_ref, v_ref, su_ref, ss_ref,
                                 sv_ref, sx_ref, packed=packed))


def _kernel_grouped_qa(su_ref, sv_ref, x_ref, u_ref, s_ref, v_ref, ss_ref,
                       sx_ref, out_ref, z_scr, y_scr, *, b: int,
                       n_r_tiles: int, packed: bool = False):
    g = pl.program_id(0)
    _stages(x_ref, out_ref, z_scr, y_scr, b=b, n_r_tiles=n_r_tiles,
            rt_axis=2, acc1=jnp.int32,
            **_quant_act_loaders(u_ref, s_ref, v_ref, su_ref, ss_ref,
                                 sv_ref, sx_ref, g=g, packed=packed))


# ---------------------------------------------------------------------------
# pallas_call wrappers.
# ---------------------------------------------------------------------------


def _scratch(b, block_t, block_r, m):
    return [
        pltpu.VMEM((b, block_t, block_r), jnp.float32),  # z
        pltpu.VMEM((block_t, m), jnp.float32),           # y accumulator
    ]


def blast_matmul_pallas(
    x: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x: (T, n) → (T, m).  Factors: U (b,p,r), S (b,b,r), V (b,q,r).

    T must be a multiple of ``block_t`` and r of ``block_r`` (ops.py pads).
    """
    T, n = x.shape
    b, p, r = U.shape
    q = V.shape[1]
    m = b * p
    assert n == b * q, (n, b, q)
    assert T % block_t == 0 and r % block_r == 0, (T, r, block_t, block_r)
    n_t, n_rt = T // block_t, r // block_r

    kernel = functools.partial(_kernel, b=b, n_r_tiles=n_rt)
    return pl.pallas_call(
        kernel,
        grid=(n_t, n_rt, b),
        in_specs=[
            pl.BlockSpec((block_t, n), lambda t, rt, i: (t, 0)),           # x
            pl.BlockSpec((1, p, block_r), lambda t, rt, i: (i, 0, rt)),    # U
            pl.BlockSpec((b, b, block_r), lambda t, rt, i: (0, 0, rt)),    # S
            pl.BlockSpec((b, q, block_r), lambda t, rt, i: (0, 0, rt)),    # V
        ],
        out_specs=pl.BlockSpec((block_t, m), lambda t, rt, i: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, m), x.dtype),
        scratch_shapes=_scratch(b, block_t, block_r, m),
        interpret=interpret,
    )(x, U, S, V)


def blast_matmul_grouped_pallas(
    x: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Grouped fused BLAST matmul: one launch for G congruent factor sets.

    x: (T, n) shared input; U (G,b,p,r), S (G,b,b,r), V (G,b,q,r) →
    y (G, T, m).  The grid grows a leading G dimension; the x-tile block
    index ignores g, so the input tile is fetched once per (T, r) tile and
    revisited across the whole group.
    """
    T, n = x.shape
    G, b, p, r = U.shape
    q = V.shape[2]
    m = b * p
    assert n == b * q, (n, b, q)
    assert T % block_t == 0 and r % block_r == 0, (T, r, block_t, block_r)
    n_t, n_rt = T // block_t, r // block_r

    kernel = functools.partial(_kernel_grouped, b=b, n_r_tiles=n_rt)
    return pl.pallas_call(
        kernel,
        grid=(G, n_t, n_rt, b),
        in_specs=[
            pl.BlockSpec((block_t, n), lambda g, t, rt, i: (t, 0)),
            pl.BlockSpec((1, 1, p, block_r),
                         lambda g, t, rt, i: (g, i, 0, rt)),
            pl.BlockSpec((1, b, b, block_r),
                         lambda g, t, rt, i: (g, 0, 0, rt)),
            pl.BlockSpec((1, b, q, block_r),
                         lambda g, t, rt, i: (g, 0, 0, rt)),
        ],
        out_specs=pl.BlockSpec((1, block_t, m), lambda g, t, rt, i: (g, t, 0)),
        out_shape=jax.ShapeDtypeStruct((G, T, m), x.dtype),
        scratch_shapes=_scratch(b, block_t, block_r, m),
        interpret=interpret,
    )(x, U, S, V)


def blast_matmul_q_pallas(
    x: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    su: jax.Array,
    ss: jax.Array,
    sv: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused int8 BLAST matmul.  x: (T, n) float → (T, m) float.

    U (b,p,r), S (b,b,r), V (b,q,r) are int8 codes; su (b,), ss (b,b),
    sv (b,) are the per-block float32 scales — su/sv via scalar prefetch,
    ss as a (b, b, 1) fp32 VMEM operand (vectorized per-row reads).
    Same tiling contract as ``blast_matmul_pallas``.
    """
    T, n = x.shape
    b, p, r = U.shape
    q = V.shape[1]
    m = b * p
    assert n == b * q, (n, b, q)
    assert T % block_t == 0 and r % block_r == 0, (T, r, block_t, block_r)
    n_t, n_rt = T // block_t, r // block_r

    kernel = functools.partial(_kernel_q, b=b, n_r_tiles=n_rt)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_t, n_rt, b),
        in_specs=[
            pl.BlockSpec((block_t, n), lambda t, rt, i, *_: (t, 0)),
            pl.BlockSpec((1, p, block_r), lambda t, rt, i, *_: (i, 0, rt)),
            pl.BlockSpec((b, b, block_r), lambda t, rt, i, *_: (0, 0, rt)),
            pl.BlockSpec((b, q, block_r), lambda t, rt, i, *_: (0, 0, rt)),
            pl.BlockSpec((b, b, 1), lambda t, rt, i, *_: (0, 0, 0)),   # ss
        ],
        out_specs=pl.BlockSpec((block_t, m), lambda t, rt, i, *_: (t, 0)),
        scratch_shapes=_scratch(b, block_t, block_r, m),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, m), x.dtype),
        interpret=interpret,
    )(su.astype(jnp.float32), sv.astype(jnp.float32),
      x, U, S, V, ss.astype(jnp.float32).reshape(b, b, 1))


def blast_matmul_q4_pallas(
    x: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    su: jax.Array,
    ss: jax.Array,
    sv: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused int4 BLAST matmul over *nibble-packed* factors.

    U (b,p,r/2), S (b,b,r/2), V (b,q,r/2) are uint8 nibble pairs packed
    along r (``quant/qarray.py`` layout) — they stay packed in HBM and VMEM
    and are unpacked in-register, so factor HBM reads are half the int8
    kernel's.  Logical r = 2·packed bytes must be a multiple of ``block_r``
    (even by construction); scales as in ``blast_matmul_q_pallas``.
    """
    T, n = x.shape
    b, p, r2 = U.shape
    q = V.shape[1]
    r = 2 * r2
    m = b * p
    assert n == b * q, (n, b, q)
    assert block_r % 2 == 0, block_r
    assert T % block_t == 0 and r % block_r == 0, (T, r, block_t, block_r)
    n_t, n_rt = T // block_t, r // block_r
    rb = block_r // 2  # packed bytes per r tile

    kernel = functools.partial(_kernel_q, b=b, n_r_tiles=n_rt, packed=True)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_t, n_rt, b),
        in_specs=[
            pl.BlockSpec((block_t, n), lambda t, rt, i, *_: (t, 0)),
            pl.BlockSpec((1, p, rb), lambda t, rt, i, *_: (i, 0, rt)),
            pl.BlockSpec((b, b, rb), lambda t, rt, i, *_: (0, 0, rt)),
            pl.BlockSpec((b, q, rb), lambda t, rt, i, *_: (0, 0, rt)),
            pl.BlockSpec((b, b, 1), lambda t, rt, i, *_: (0, 0, 0)),   # ss
        ],
        out_specs=pl.BlockSpec((block_t, m), lambda t, rt, i, *_: (t, 0)),
        scratch_shapes=_scratch(b, block_t, block_r, m),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, m), x.dtype),
        interpret=interpret,
    )(su.astype(jnp.float32), sv.astype(jnp.float32),
      x, U, S, V, ss.astype(jnp.float32).reshape(b, b, 1))


def blast_matmul_grouped_q_pallas(
    x: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    su: jax.Array,
    ss: jax.Array,
    sv: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Grouped int8-factor BLAST matmul: one launch, one x-tile load.

    x (T, n); U (G,b,p,r), S (G,b,b,r), V (G,b,q,r) int8 codes; su (G,b),
    ss (G,b,b), sv (G,b) float scales → y (G, T, m).
    """
    T, n = x.shape
    G, b, p, r = U.shape
    q = V.shape[2]
    m = b * p
    assert n == b * q, (n, b, q)
    assert T % block_t == 0 and r % block_r == 0, (T, r, block_t, block_r)
    n_t, n_rt = T // block_t, r // block_r

    kernel = functools.partial(_kernel_grouped_q, b=b, n_r_tiles=n_rt)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(G, n_t, n_rt, b),
        in_specs=[
            pl.BlockSpec((block_t, n), lambda g, t, rt, i, *_: (t, 0)),
            pl.BlockSpec((1, 1, p, block_r),
                         lambda g, t, rt, i, *_: (g, i, 0, rt)),
            pl.BlockSpec((1, b, b, block_r),
                         lambda g, t, rt, i, *_: (g, 0, 0, rt)),
            pl.BlockSpec((1, b, q, block_r),
                         lambda g, t, rt, i, *_: (g, 0, 0, rt)),
            pl.BlockSpec((1, b, b, 1), lambda g, t, rt, i, *_: (g, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, m),
                               lambda g, t, rt, i, *_: (g, t, 0)),
        scratch_shapes=_scratch(b, block_t, block_r, m),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, T, m), x.dtype),
        interpret=interpret,
    )(su.astype(jnp.float32), sv.astype(jnp.float32),
      x, U, S, V, ss.astype(jnp.float32).reshape(G, b, b, 1))


def blast_matmul_grouped_q4_pallas(
    x: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    su: jax.Array,
    ss: jax.Array,
    sv: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Grouped *nibble-packed* int4 BLAST matmul: PR 5's two wins combined —
    one launch for G congruent factor sets AND half the factor HBM reads.

    x (T, n) float; U (G,b,p,r/2), S (G,b,b,r/2), V (G,b,q,r/2) uint8
    nibble pairs packed along r; su (G,b), ss (G,b,b), sv (G,b) float
    scales → y (G, T, m).  Factors stay packed in HBM and VMEM and unpack
    in-register to plane order (exact — the r contraction is
    permutation-invariant; pad bytes are zero codes).
    """
    T, n = x.shape
    G, b, p, r2 = U.shape
    q = V.shape[2]
    r = 2 * r2
    m = b * p
    assert n == b * q, (n, b, q)
    assert block_r % 2 == 0, block_r
    assert T % block_t == 0 and r % block_r == 0, (T, r, block_t, block_r)
    n_t, n_rt = T // block_t, r // block_r
    rb = block_r // 2  # packed bytes per r tile

    kernel = functools.partial(_kernel_grouped_q, b=b, n_r_tiles=n_rt,
                               packed=True)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(G, n_t, n_rt, b),
        in_specs=[
            pl.BlockSpec((block_t, n), lambda g, t, rt, i, *_: (t, 0)),
            pl.BlockSpec((1, 1, p, rb), lambda g, t, rt, i, *_: (g, i, 0, rt)),
            pl.BlockSpec((1, b, b, rb), lambda g, t, rt, i, *_: (g, 0, 0, rt)),
            pl.BlockSpec((1, b, q, rb), lambda g, t, rt, i, *_: (g, 0, 0, rt)),
            pl.BlockSpec((1, b, b, 1), lambda g, t, rt, i, *_: (g, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, m),
                               lambda g, t, rt, i, *_: (g, t, 0)),
        scratch_shapes=_scratch(b, block_t, block_r, m),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, T, m), x.dtype),
        interpret=interpret,
    )(su.astype(jnp.float32), sv.astype(jnp.float32),
      x, U, S, V, ss.astype(jnp.float32).reshape(G, b, b, 1))


# ---------------------------------------------------------------------------
# Integer-activation (W8A8 / W4A8) wrappers: x arrives as int8 per-token
# codes + fp32 per-row scales; stage 1 is an int8×int8 → int32 MXU dot.
# ---------------------------------------------------------------------------


def _act_call(xq, sx, U, S, V, su, ss, sv, *, packed, block_t, block_r,
              interpret, out_dtype):
    T, n = xq.shape
    b, p, rU = U.shape
    q = V.shape[1]
    r = 2 * rU if packed else rU
    m = b * p
    assert xq.dtype == jnp.int8, xq.dtype
    assert sx.shape == (T, 1), (sx.shape, T)
    assert n == b * q, (n, b, q)
    if packed:
        assert block_r % 2 == 0, block_r
    assert T % block_t == 0 and r % block_r == 0, (T, r, block_t, block_r)
    n_t, n_rt = T // block_t, r // block_r
    rb = block_r // 2 if packed else block_r

    kernel = functools.partial(_kernel_qa, b=b, n_r_tiles=n_rt, packed=packed)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_t, n_rt, b),
        in_specs=[
            pl.BlockSpec((block_t, n), lambda t, rt, i, *_: (t, 0)),
            pl.BlockSpec((1, p, rb), lambda t, rt, i, *_: (i, 0, rt)),
            pl.BlockSpec((b, b, rb), lambda t, rt, i, *_: (0, 0, rt)),
            pl.BlockSpec((b, q, rb), lambda t, rt, i, *_: (0, 0, rt)),
            pl.BlockSpec((b, b, 1), lambda t, rt, i, *_: (0, 0, 0)),    # ss
            pl.BlockSpec((block_t, 1), lambda t, rt, i, *_: (t, 0)),    # sx
        ],
        out_specs=pl.BlockSpec((block_t, m), lambda t, rt, i, *_: (t, 0)),
        scratch_shapes=_scratch(b, block_t, block_r, m),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, m), out_dtype),
        interpret=interpret,
    )(su.astype(jnp.float32), sv.astype(jnp.float32),
      xq, U, S, V, ss.astype(jnp.float32).reshape(b, b, 1),
      sx.astype(jnp.float32))


def blast_matmul_w8a8_pallas(
    xq: jax.Array,
    sx: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    su: jax.Array,
    ss: jax.Array,
    sv: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Fused W8A8 BLAST matmul: int8 activation codes × int8 factor codes.

    xq (T, n) int8 per-token codes, sx (T, 1) fp32 per-row scales
    (``quant/qarray.py::quantize_act`` layout); factors/scales as in
    ``blast_matmul_q_pallas`` → (T, m) ``out_dtype``.  Stage 1 contracts
    raw codes in int32 (exact) and dequantizes once with ``sx · sv_j``.
    """
    return _act_call(xq, sx, U, S, V, su, ss, sv, packed=False,
                     block_t=block_t, block_r=block_r, interpret=interpret,
                     out_dtype=out_dtype)


def blast_matmul_w4a8_pallas(
    xq: jax.Array,
    sx: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    su: jax.Array,
    ss: jax.Array,
    sv: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Fused W4A8 BLAST matmul: int8 activation codes × nibble-packed int4
    factors (``blast_matmul_q4_pallas`` packing; V unpacks to int8 in
    register so stage 1 stays an integer MXU dot)."""
    return _act_call(xq, sx, U, S, V, su, ss, sv, packed=True,
                     block_t=block_t, block_r=block_r, interpret=interpret,
                     out_dtype=out_dtype)


def _grouped_act_call(xq, sx, U, S, V, su, ss, sv, *, packed, block_t,
                      block_r, interpret, out_dtype):
    T, n = xq.shape
    G, b, p, rU = U.shape
    q = V.shape[2]
    r = 2 * rU if packed else rU
    m = b * p
    assert xq.dtype == jnp.int8, xq.dtype
    assert sx.shape == (T, 1), (sx.shape, T)
    assert n == b * q, (n, b, q)
    if packed:
        assert block_r % 2 == 0, block_r
    assert T % block_t == 0 and r % block_r == 0, (T, r, block_t, block_r)
    n_t, n_rt = T // block_t, r // block_r
    rb = block_r // 2 if packed else block_r

    kernel = functools.partial(_kernel_grouped_qa, b=b, n_r_tiles=n_rt,
                               packed=packed)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(G, n_t, n_rt, b),
        in_specs=[
            pl.BlockSpec((block_t, n), lambda g, t, rt, i, *_: (t, 0)),
            pl.BlockSpec((1, 1, p, rb), lambda g, t, rt, i, *_: (g, i, 0, rt)),
            pl.BlockSpec((1, b, b, rb), lambda g, t, rt, i, *_: (g, 0, 0, rt)),
            pl.BlockSpec((1, b, q, rb), lambda g, t, rt, i, *_: (g, 0, 0, rt)),
            pl.BlockSpec((1, b, b, 1), lambda g, t, rt, i, *_: (g, 0, 0, 0)),
            pl.BlockSpec((block_t, 1), lambda g, t, rt, i, *_: (t, 0)),  # sx
        ],
        out_specs=pl.BlockSpec((1, block_t, m),
                               lambda g, t, rt, i, *_: (g, t, 0)),
        scratch_shapes=_scratch(b, block_t, block_r, m),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, T, m), out_dtype),
        interpret=interpret,
    )(su.astype(jnp.float32), sv.astype(jnp.float32),
      xq, U, S, V, ss.astype(jnp.float32).reshape(G, b, b, 1),
      sx.astype(jnp.float32))


def blast_matmul_grouped_w8a8_pallas(
    xq: jax.Array,
    sx: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    su: jax.Array,
    ss: jax.Array,
    sv: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Grouped W8A8: one launch for G int8 factor sets sharing one set of
    int8 activation codes (xq (T, n) int8, sx (T, 1) fp32) → (G, T, m)."""
    return _grouped_act_call(xq, sx, U, S, V, su, ss, sv, packed=False,
                             block_t=block_t, block_r=block_r,
                             interpret=interpret, out_dtype=out_dtype)


def blast_matmul_grouped_w4a8_pallas(
    xq: jax.Array,
    sx: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    su: jax.Array,
    ss: jax.Array,
    sv: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Grouped W4A8: one launch, packed int4 factors (G,b,·,r/2), shared
    int8 activation codes → (G, T, m)."""
    return _grouped_act_call(xq, sx, U, S, V, su, ss, sv, packed=True,
                             block_t=block_t, block_r=block_r,
                             interpret=interpret, out_dtype=out_dtype)
