"""Fused BLAST matmul Pallas TPU kernel (paper Alg. 1, TPU-native).

GPU version (paper App. A): three separate ``torch.bmm``/broadcast kernels,
materializing ``Z = (b, T, r)`` and ``W = (b, T, r)`` in HBM between calls.

TPU adaptation: one fused kernel.  Grid = ``(T_tiles, r_tiles, b_i)``:

  * at ``i == 0`` the stage-1 products ``z_j = x_j @ V_j[:, rt]`` for *all*
    input blocks j are computed into a VMEM scratch ``(b, T_t, r_t)`` — once
    per (T, r) tile, amortized over all b output blocks;
  * each i does the VPU coupling reduce ``w_i = Σ_j s_ij ⊙ z_j`` and the MXU
    projection ``y_i += w_i @ U_iᵀ``, accumulated in a fp32 VMEM scratch
    ``(T_t, m)`` that is flushed to HBM once per T tile.

Z and W therefore never touch HBM; the only HBM traffic is X, U/S/V (once
per T tile) and Y (once).  Block shapes are chosen in ``ops.py`` so the
resident set (x-tile + z-scratch + y-accumulator + factor tiles) fits a
16 MB v5e VMEM, with MXU-aligned (multiple-of-128) r/T tiles when possible.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, u_ref, s_ref, v_ref, out_ref, z_scr, y_scr, *, b: int,
            n_r_tiles: int):
    rt = pl.program_id(1)
    i = pl.program_id(2)
    T_t = x_ref.shape[0]
    q = v_ref.shape[1]
    p = u_ref.shape[1]
    r_t = v_ref.shape[2]

    # ---- stage 1 (once per (T, r) tile): z_j = x_j @ V_j
    @pl.when(i == 0)
    def _compute_z():
        x = x_ref[...]
        for j in range(b):  # b is static and small (≤16): unrolled
            xj = x[:, j * q:(j + 1) * q]
            z_scr[j] = jax.lax.dot_general(
                xj, v_ref[j], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

    @pl.when((rt == 0) & (i == 0))
    def _init_acc():
        y_scr[...] = jnp.zeros_like(y_scr)

    # ---- stage 2 (VPU): w_i = Σ_j s_ij ⊙ z_j
    s_i = jax.lax.dynamic_index_in_dim(s_ref[...], i, 0, keepdims=False)  # (b, r_t)
    z = z_scr[...]  # (b, T_t, r_t) fp32
    w = jnp.sum(s_i[:, None, :].astype(jnp.float32) * z, axis=0)  # (T_t, r_t)

    # ---- stage 3 (MXU): y_i += w @ U_iᵀ, accumulated over r tiles
    u_i = u_ref[0]  # (p, r_t)
    y_part = jax.lax.dot_general(
        w, u_i, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    col = i * p
    y_scr[:, pl.ds(col, p)] = y_scr[:, pl.ds(col, p)] + y_part

    # ---- flush once per T tile
    @pl.when((rt == n_r_tiles - 1) & (i == b - 1))
    def _flush():
        out_ref[...] = y_scr[...].astype(out_ref.dtype)


def _kernel_q(su_ref, ss_ref, sv_ref, x_ref, u_ref, s_ref, v_ref, out_ref,
              z_scr, y_scr, *, b: int, n_r_tiles: int):
    """int8-factor variant of ``_kernel``: U/S/V tiles arrive in VMEM as int8
    (half/quarter the HBM traffic — the whole point), are cast in-register
    for the MXU/VPU ops, and each stage's per-block scale (scalar-prefetched
    into SMEM) multiplies the stage *output* — quantized factors never
    round-trip through HBM as floats."""
    rt = pl.program_id(1)
    i = pl.program_id(2)
    q = v_ref.shape[1]
    p = u_ref.shape[1]

    # ---- stage 1 (once per (T, r) tile): z_j = (x_j @ V_j^int) · sv_j
    @pl.when(i == 0)
    def _compute_z():
        x = x_ref[...]
        for j in range(b):
            xj = x[:, j * q:(j + 1) * q]
            zj = jax.lax.dot_general(
                xj, v_ref[j].astype(x.dtype), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            z_scr[j] = zj * sv_ref[j]

    @pl.when((rt == 0) & (i == 0))
    def _init_acc():
        y_scr[...] = jnp.zeros_like(y_scr)

    # ---- stage 2 (VPU): w_i = Σ_j (ss_ij · s_ij^int) ⊙ z_j
    s_i = jax.lax.dynamic_index_in_dim(s_ref[...], i, 0, keepdims=False)
    ss_i = jnp.stack([ss_ref[i, j] for j in range(b)])       # (b,) from SMEM
    s_deq = s_i.astype(jnp.float32) * ss_i[:, None]          # (b, r_t)
    w = jnp.sum(s_deq[:, None, :] * z_scr[...], axis=0)      # (T_t, r_t)

    # ---- stage 3 (MXU): y_i += (w @ U_i^int ᵀ) · su_i
    u_i = u_ref[0].astype(jnp.float32)                       # (p, r_t)
    y_part = jax.lax.dot_general(
        w, u_i, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    col = i * p
    y_scr[:, pl.ds(col, p)] = y_scr[:, pl.ds(col, p)] + y_part * su_ref[i]

    @pl.when((rt == n_r_tiles - 1) & (i == b - 1))
    def _flush():
        out_ref[...] = y_scr[...].astype(out_ref.dtype)


def blast_matmul_q_pallas(
    x: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    su: jax.Array,
    ss: jax.Array,
    sv: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused int8 BLAST matmul.  x: (T, n) float → (T, m) float.

    U (b,p,r), S (b,b,r), V (b,q,r) are int8 codes; su (b,), ss (b,b),
    sv (b,) are the per-block float32 scales, delivered via scalar prefetch.
    Same tiling contract as ``blast_matmul_pallas``.
    """
    T, n = x.shape
    b, p, r = U.shape
    q = V.shape[1]
    m = b * p
    assert n == b * q, (n, b, q)
    assert T % block_t == 0 and r % block_r == 0, (T, r, block_t, block_r)
    n_t, n_rt = T // block_t, r // block_r

    kernel = functools.partial(_kernel_q, b=b, n_r_tiles=n_rt)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_t, n_rt, b),
        in_specs=[
            pl.BlockSpec((block_t, n), lambda t, rt, i, *_: (t, 0)),
            pl.BlockSpec((1, p, block_r), lambda t, rt, i, *_: (i, 0, rt)),
            pl.BlockSpec((b, b, block_r), lambda t, rt, i, *_: (0, 0, rt)),
            pl.BlockSpec((b, q, block_r), lambda t, rt, i, *_: (0, 0, rt)),
        ],
        out_specs=pl.BlockSpec((block_t, m), lambda t, rt, i, *_: (t, 0)),
        scratch_shapes=[
            pltpu.VMEM((b, block_t, block_r), jnp.float32),  # z
            pltpu.VMEM((block_t, m), jnp.float32),           # y accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, m), x.dtype),
        interpret=interpret,
    )(su.astype(jnp.float32), ss.astype(jnp.float32), sv.astype(jnp.float32),
      x, U, S, V)


def blast_matmul_pallas(
    x: jax.Array,
    U: jax.Array,
    S: jax.Array,
    V: jax.Array,
    *,
    block_t: int = 128,
    block_r: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x: (T, n) → (T, m).  Factors: U (b,p,r), S (b,b,r), V (b,q,r).

    T must be a multiple of ``block_t`` and r of ``block_r`` (ops.py pads).
    """
    T, n = x.shape
    b, p, r = U.shape
    q = V.shape[1]
    m = b * p
    assert n == b * q, (n, b, q)
    assert T % block_t == 0 and r % block_r == 0, (T, r, block_t, block_r)
    n_t, n_rt = T // block_t, r // block_r

    grid = (n_t, n_rt, b)
    kernel = functools.partial(_kernel, b=b, n_r_tiles=n_rt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, n), lambda t, rt, i: (t, 0)),           # x
            pl.BlockSpec((1, p, block_r), lambda t, rt, i: (i, 0, rt)),    # U
            pl.BlockSpec((b, b, block_r), lambda t, rt, i: (0, 0, rt)),    # S
            pl.BlockSpec((b, q, block_r), lambda t, rt, i: (0, 0, rt)),    # V
        ],
        out_specs=pl.BlockSpec((block_t, m), lambda t, rt, i: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, m), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((b, block_t, block_r), jnp.float32),  # z
            pltpu.VMEM((block_t, m), jnp.float32),           # y accumulator
        ],
        interpret=interpret,
    )(x, U, S, V)
