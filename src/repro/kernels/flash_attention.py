"""Flash-attention Pallas TPU kernel: causal / sliding-window / GQA.

Online-softmax over KV tiles (grid innermost dim), fp32 running (m, l, acc)
in VMEM scratch, one output flush per Q tile.  Fully-masked KV tiles (beyond
the causal diagonal or outside the sliding window) are skipped with
``pl.when`` so long-context prefill does ~half (causal) or O(window/S)
(local) of the dense work — matching how the roofline model accounts it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int | None, q_offset,
               kv_len: int, n_kv_tiles: int, block_q: int, block_kv: int):
    """Online-softmax tile update shared by the fixed-offset kernel and the
    prefill-at-offset kernel (``q_offset`` is a python int or a traced int32
    scalar; with a traced offset the block-skip predicate turns dynamic and
    still short-circuits via ``pl.when``)."""
    tq = pl.program_id(1)
    skv = pl.program_id(2)

    q_pos = q_offset + tq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    k_pos = skv * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)

    @pl.when(skv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # --- block-level skip: is any (q, k) pair in this tile live?
    q_min = q_offset + tq * block_q
    q_max = q_offset + (tq + 1) * block_q - 1
    k_min = skv * block_kv
    k_max = (skv + 1) * block_kv - 1
    live = k_min < kv_len
    if causal:
        live &= k_min <= q_max
    if window is not None:
        live &= k_max > q_min - window

    @pl.when(live)
    def _update():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(skv == n_kv_tiles - 1)
    def _flush():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def _prefill_kernel(offs_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                    acc_scr, *, scale: float, causal: bool,
                    window: int | None, n_q_heads: int, kv_len: int,
                    n_kv_tiles: int, block_q: int, block_kv: int):
    """Prefill-at-offset: the causal mask is shifted by the per-sequence
    scalar-prefetched offset (continuous batching: each batch row prefills a
    C-token chunk at its own absolute position against a positional cache)."""
    off = offs_ref[pl.program_id(0) // n_q_heads]
    _attn_body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
               scale=scale, causal=causal, window=window, q_offset=off,
               kv_len=kv_len, n_kv_tiles=n_kv_tiles, block_q=block_q,
               block_kv=block_kv)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    kv_len: int | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D) → (B, Hq, T, D).

    T and S must be multiples of the block sizes (ops.py pads); ``kv_len``
    masks padded key positions.
    """
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    kv_len = S if kv_len is None else kv_len
    assert T % block_q == 0 and S % block_kv == 0
    n_tq, n_skv = T // block_q, S // block_kv

    qf = q.reshape(B * Hq, T, D)
    kf = k.reshape(B * Hkv, S, D)
    vf = v.reshape(B * Hkv, S, D)

    def kv_index(bh, tq, skv):
        return ((bh // Hq) * Hkv + (bh % Hq) // G, skv, 0)

    kernel = functools.partial(
        _attn_body, scale=1.0 / (D ** 0.5), causal=causal, window=window,
        q_offset=q_offset, kv_len=kv_len, n_kv_tiles=n_skv,
        block_q=block_q, block_kv=block_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, n_tq, n_skv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, tq, skv: (bh, tq, 0)),
            pl.BlockSpec((1, block_kv, D), kv_index),
            pl.BlockSpec((1, block_kv, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, tq, skv: (bh, tq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, T, D)


def flash_attention_prefill_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_offsets: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    kv_len: int | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Chunked-prefill flash attention at per-sequence offsets.

    q: (B, Hq, C, D) — one C-token chunk per batch row; k, v: (B, Hkv, S, D)
    — the positionally-laid-out KV cache (slot index == absolute position,
    chunk keys already written); q_offsets: (B,) int32 absolute position of
    each row's first chunk token.  Query (b, t) attends to key j iff
    ``j <= q_offsets[b] + t`` (causal shifted by the offset) and, with a
    window, ``j > q_offsets[b] + t - window``.  The offsets ride in via
    scalar prefetch so fully-masked KV tiles beyond each row's own diagonal
    are still skipped (C×max_len grid, ~offset/S of it live).
    """
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    kv_len = S if kv_len is None else kv_len
    assert T % block_q == 0 and S % block_kv == 0
    n_tq, n_skv = T // block_q, S // block_kv

    qf = q.reshape(B * Hq, T, D)
    kf = k.reshape(B * Hkv, S, D)
    vf = v.reshape(B * Hkv, S, D)

    def q_index(bh, tq, skv, offs):
        return (bh, tq, 0)

    def kv_index(bh, tq, skv, offs):
        return ((bh // Hq) * Hkv + (bh % Hq) // G, skv, 0)

    kernel = functools.partial(
        _prefill_kernel, scale=1.0 / (D ** 0.5), causal=causal, window=window,
        n_q_heads=Hq, kv_len=kv_len, n_kv_tiles=n_skv,
        block_q=block_q, block_kv=block_kv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hq, n_tq, n_skv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_index),
            pl.BlockSpec((1, block_kv, D), kv_index),
            pl.BlockSpec((1, block_kv, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_index),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, T, D), q.dtype),
        interpret=interpret,
    )(q_offsets.astype(jnp.int32), qf, kf, vf)
    return out.reshape(B, Hq, T, D)
