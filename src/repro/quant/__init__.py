"""Quantized parameter / cache storage (per-block symmetric int8, optional
packed int4) — the serving-memory half of the BLAST story.

- ``QArray``        {q, scale} pytree; survives vmap stacking & checkpoints
- ``quantize`` / ``dequantize`` / ``int_values``  per-block weight codecs
- ``quantize_rows`` / ``dequantize_rows``         per-row cache codecs
- ``quantize_act`` / ``dequantize_act``           per-token activation codec
- ``QuantConfig``   the knob threaded through configs → engine → benchmarks
"""

from repro.quant.qarray import (  # noqa: F401
    QArray,
    QuantConfig,
    dequantize,
    dequantize_act,
    dequantize_rows,
    int_values,
    is_qarray,
    pack_int4,
    pack_state_cache,
    plane_order,
    quantize,
    quantize_act,
    quantize_rows,
    unpack_state_cache,
    tree_is_quantized,
    tree_nbytes,
    unpack_int4,
    unpack_int4_planes,
)
