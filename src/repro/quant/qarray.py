"""Per-block symmetric integer quantization: the ``QArray = {q, scale}``
pytree and the primitives every storage-format-aware apply path builds on.

Conventions
-----------
``quantize(x, bits, block_axes)`` shares ONE symmetric scale per *block*: the
max-abs is reduced over ``block_axes`` (keepdims), so ``scale`` broadcasts
against ``q`` and dequantization is ``q * scale``.  Structured factors use
their natural blocks (e.g. one scale per BLAST ``U_i`` / ``V_j`` block and
one per ``S_ij`` coupling vector), dense weights use per-output-channel
scales — in every case the scale is constant along the contracted axis, so
dequantization commutes with the innermost matmul and can be fused *after*
it (the weight tensor never round-trips through memory as floats).

Zero-block safety: an all-zero block gets ``scale = 1`` (not 0), so
``q = 0`` and dequantization returns exactly zero — no 0/0.

int4 values are stored two-per-byte (packed along the last axis, zero-padded
to even length); ``int_values`` unpacks back to int8-valued logical layout.
Only the *last* dimension is recorded statically, so a ``QArray`` survives
``jax.vmap`` stacking (MoE experts, scan-over-layers cycles) unchanged.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_QMAX = {8: 127, 4: 7}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QArray:
    """Quantized tensor: integer values + per-block scales.

    q:        int8 codes (or uint8 nibble-pairs when ``bits == 4``)
    scale:    float scales, broadcastable against the logical values
    bits:     8 or 4 (static)
    last_dim: logical size of the last axis (static; differs from
              ``q.shape[-1]`` only for packed int4)
    """

    q: jax.Array
    scale: jax.Array
    bits: int = 8
    last_dim: int | None = None

    def tree_flatten(self):
        return (self.q, self.scale), (self.bits, self.last_dim)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def nbytes(self) -> int:
        return self.q.nbytes + self.scale.nbytes

    @property
    def shape(self) -> tuple[int, ...]:
        d = self.q.shape[-1] if self.last_dim is None else self.last_dim
        return (*self.q.shape[:-1], d)


def is_qarray(x) -> bool:
    return isinstance(x, QArray)


def tree_is_quantized(tree) -> bool:
    """True if any node in ``tree`` is a QArray."""
    return any(is_qarray(l) for l in
               jax.tree.leaves(tree, is_leaf=is_qarray))


def tree_nbytes(tree) -> int:
    """Total bytes of all array leaves (QArray counts q + scale)."""
    return sum(l.nbytes for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# int4 nibble packing (two values per byte along the last axis).
# ---------------------------------------------------------------------------


def pack_int4(v: jax.Array) -> jax.Array:
    """v: int8 values in [-7, 7], (..., D) → uint8 (..., ceil(D/2))."""
    D = v.shape[-1]
    if D % 2:
        v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, 1)])
    u = v.astype(jnp.uint8) & 0xF          # two's-complement low nibble
    return u[..., 0::2] | (u[..., 1::2] << 4)


def unpack_int4(p: jax.Array, last_dim: int) -> jax.Array:
    """uint8 nibble-pairs (..., P) → int8 values (..., last_dim)."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = (p >> 4).astype(jnp.int8)
    v = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], 2 * p.shape[-1])
    v = jnp.where(v >= 8, v - 16, v)       # sign-extend the nibble
    return v[..., :last_dim]


def unpack_int4_planes(p: jax.Array) -> jax.Array:
    """Kernel-layout unpack: uint8 nibble-pairs (..., P) → int8 (..., 2P) in
    *plane order* ``[low nibbles | high nibbles]`` — i.e. logical positions
    ``[0, 2, 4, …, 1, 3, 5, …]`` of the interleaved ``pack_int4`` layout.

    This is the in-register unpack the fused int4 Pallas kernel
    (``kernels/blast_matmul.py::blast_matmul_q4_pallas``) applies to every
    VMEM tile: no re-interleave is needed because the BLAST contraction
    reduces over the packed (rank) axis, which is permutation-invariant as
    long as U, S and V unpack identically.  Exposed here so oracles/tests
    can mirror the kernel's exact layout.
    """
    lo = (p & 0xF).astype(jnp.int8)
    hi = (p >> 4).astype(jnp.int8)
    v = jnp.concatenate([lo, hi], axis=-1)
    return jnp.where(v >= 8, v - 16, v)


def plane_order(r: int) -> jax.Array:
    """Permutation mapping plane order → logical order for a packed length
    of ``ceil(r/2)`` bytes: ``unpack_int4_planes(p)[..., plane_order(r)] ==
    unpack_int4(p, r)`` (dropping the odd-r pad nibble)."""
    import numpy as np
    half = (r + 1) // 2
    idx = np.empty((r,), np.int32)
    idx[0::2] = np.arange(0, half)          # even logical ranks: low plane
    idx[1::2] = np.arange(half, half + r // 2)   # odd ranks: high plane
    return jnp.asarray(idx)


# ---------------------------------------------------------------------------
# Core quantize / dequantize.
# ---------------------------------------------------------------------------


def _block_scale(x: jax.Array, qmax: int,
                 block_axes: tuple[int, ...] | None) -> jax.Array:
    amax = jnp.max(jnp.abs(x), axis=block_axes, keepdims=True)
    return jnp.where(amax > 0, amax / qmax, 1.0)


def quantize(x: jax.Array, *, bits: int = 8,
             block_axes: tuple[int, ...] | None = None,
             scale_dtype=jnp.float32) -> QArray:
    """Per-block symmetric quantization.  One scale per block, where a block
    is the slice spanned by ``block_axes`` (None = one scale per tensor)."""
    qmax = _QMAX[bits]
    xf = x.astype(jnp.float32)
    scale = _block_scale(xf, qmax, block_axes)
    v = jnp.clip(jnp.round(xf / scale), -qmax, qmax).astype(jnp.int8)
    last_dim = x.shape[-1]
    if bits == 4:
        v = pack_int4(v)
    return QArray(q=v, scale=scale.astype(scale_dtype), bits=bits,
                  last_dim=last_dim)


def int_values(qa: QArray) -> jax.Array:
    """The logical int8 codes (unpacks int4)."""
    if qa.bits == 4:
        return unpack_int4(qa.q, qa.q.shape[-1] * 2 if qa.last_dim is None
                           else qa.last_dim)
    return qa.q


def dequantize(qa: QArray, dtype=None) -> jax.Array:
    y = int_values(qa).astype(jnp.float32) * qa.scale.astype(jnp.float32)
    return y if dtype is None else y.astype(dtype)


# ---------------------------------------------------------------------------
# Row-wise cache quantization (KV / latent / recurrent-state caches).
# ---------------------------------------------------------------------------


def quantize_rows(t: jax.Array, scale_dtype=jnp.bfloat16
                  ) -> tuple[jax.Array, jax.Array]:
    """t: (..., D) → int8 codes (..., D) + per-row scales (...,).

    The per-(slot, head)-row int8 layout every cache family shares: one
    scale per last-axis vector, zero-guarded like ``quantize``."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(scale_dtype)


def dequantize_rows(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# Per-token activation quantization (the A8 half of W8A8 / W4A8).
# ---------------------------------------------------------------------------


def quantize_act(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., D) → int8 codes (..., D) + fp32 per-row scales (..., 1).

    One symmetric scale per *token* (row of ``x``): the integer kernels
    contract the codes over D in int32 and fuse ``sx * s_factor`` into the
    per-stage dequant, so the scale must be constant along the contracted
    axis — per-row is the finest granularity that satisfies that.  Scales
    stay fp32 (activations re-enter every layer; bf16 scale rounding would
    compound) and keep a trailing unit axis so they broadcast against both
    the codes and the kernel's stage-1 output.  Zero rows get scale 1 with
    all-zero codes, so dequantization is exactly zero."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_act(q: jax.Array, scale: jax.Array, dtype=None) -> jax.Array:
    y = q.astype(jnp.float32) * scale.astype(jnp.float32)
    return y if dtype is None else y.astype(dtype)


def pack_state_cache(quantized: bool, conv: jax.Array, h: jax.Array) -> dict:
    """Recurrent-mixer cache write (SSD / RG-LRU): conv tail + state.

    With ``quantized`` both store int8 with per-row scales — bf16 scales for
    the conv tail (token-cache convention), fp32 for the state ``h``, which
    re-enters the scan every step and cannot afford scale rounding."""
    if quantized:
        cq, cs = quantize_rows(conv)
        hq, hs = quantize_rows(h, scale_dtype=jnp.float32)
        return {"conv": cq, "conv_scale": cs, "h": hq, "h_scale": hs}
    return {"conv": conv, "h": h}


def unpack_state_cache(quantized: bool, cache: dict, dtype):
    """Inverse of ``pack_state_cache`` → (conv, h); h always fp32."""
    if quantized:
        return (dequantize_rows(cache["conv"], cache["conv_scale"], dtype),
                dequantize_rows(cache["h"], cache["h_scale"], jnp.float32))
    return cache["conv"], cache["h"]


# ---------------------------------------------------------------------------
# Config knob (threaded through configs/base.py, serve, checkpoints).
# ---------------------------------------------------------------------------


_WEIGHT_MODES = ("none", "int8", "int4")
_CACHE_MODES = ("none", "int8")
_ACT_MODES = ("none", "int8")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """What gets quantized at serving time.

    weights:     parameter storage for structured linears
                 ("none"|"int8"|"int4")
    cache:       KV / latent / recurrent-state caches ("none"|"int8")
    activations: per-token int8 layer inputs feeding integer contractions
                 ("none"|"int8"); requires quantized weights — the integer
                 kernels contract weight codes against activation codes, so
                 there is no A8-with-float-weights path.
    """

    weights: str = "none"
    cache: str = "none"
    activations: str = "none"

    def __post_init__(self):
        if self.weights not in _WEIGHT_MODES:
            raise ValueError(f"quant.weights must be one of {_WEIGHT_MODES}")
        if self.cache not in _CACHE_MODES:
            raise ValueError(f"quant.cache must be one of {_CACHE_MODES}")
        if self.activations not in _ACT_MODES:
            raise ValueError(
                f"quant.activations must be one of {_ACT_MODES}")
        if self.activations != "none" and self.weights == "none":
            raise ValueError(
                "quant.activations requires quantized weights "
                "(set quant.weights to int8 or int4)")

    @property
    def weight_bits(self) -> int | None:
        return {"none": None, "int8": 8, "int4": 4}[self.weights]

    @property
    def act_bits(self) -> int | None:
        return {"none": None, "int8": 8}[self.activations]

    @property
    def enabled(self) -> bool:
        return (self.weights != "none" or self.cache != "none"
                or self.activations != "none")
