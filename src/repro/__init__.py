"""BLAST reproduction package.

Importing the package installs the JAX API compat shims (see ``compat.py``)
so all entry points — launchers, tests, subprocess dry-runs — see the same
mesh/AxisType surface regardless of the pinned jax version.
"""

from repro import compat as _compat

_compat.install()
