"""Whole-model compression driver (paper §4.2).

Compress dense layer weights into any supported structure:

  * ``blast``      — Algorithm 2 (preconditioned GD factorization)
  * ``low_rank``   — truncated SVD (optimal in Frobenius norm)
  * ``block_diag`` — diagonal-block extraction (optimal in Frobenius norm)
  * ``monarch``    — Adam fit of the Frobenius loss (no closed form for the
                     generalized rectangular Monarch)

``compress_linear`` handles one weight; ``compress_tree`` walks a pytree of
dense weights with a registry of target LinearSpecs (built by the model).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import factorize as fct
from repro.core.structures import LinearSpec, StructureConfig, make_linear

Params = dict[str, jax.Array]


def calibrate_ranks(spectra: dict[str, "np.ndarray"], frac: float,
                    *, min_rank: int = 1) -> dict[str, int]:
    """Per-layer draft ranks from factor energy spectra.

    ``spectra`` maps a linear's name to its ``structures.rank_spectrum``
    (length-r energy vector); ``frac`` is the global rank-budget fraction
    the draft keeps.  Each component's energy is normalized to a *share* of
    its own linear's total, all shares are pooled, and the globally largest
    shares are kept until ~``frac`` of the total rank budget is used — so
    flat-spectrum layers keep more of their rank and spiky layers donate
    theirs.  Returns name → r' with every r' in [min_rank, r]; ``frac >= 1``
    keeps everything (truncation becomes the identity)."""
    shares: dict[str, np.ndarray] = {}
    sizes: dict[str, int] = {}
    for name, e in spectra.items():
        e = np.asarray(e, np.float64).reshape(-1)
        tot = float(e.sum())
        shares[name] = e / tot if tot > 0 else np.full(e.shape, 1.0 / e.size)
        sizes[name] = int(e.size)
    total = sum(sizes.values())
    keep = int(round(min(max(float(frac), 0.0), 1.0) * total))
    keep = max(keep, min_rank * len(spectra))
    if keep >= total:
        return dict(sizes)
    pool = np.sort(np.concatenate(list(shares.values())))[::-1]
    tau = pool[keep - 1]
    return {name: int(min(max(int((s >= tau).sum()), min_rank), sizes[name]))
            for name, s in shares.items()}


def _svd_low_rank(w: jax.Array, t: int) -> Params:
    """w: (d_in, d_out) → {w_down (d_in, t), w_up (t, d_out)}."""
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    t = min(t, s.shape[0])
    return {
        "w_down": (u[:, :t] * jnp.sqrt(s[:t])).astype(w.dtype),
        "w_up": (jnp.sqrt(s[:t])[:, None] * vt[:t]).astype(w.dtype),
    }


def _block_diag_extract(w: jax.Array, b: int) -> Params:
    """Optimal block-diagonal approx = diagonal blocks of w (d_in, d_out)."""
    d_in, d_out = w.shape
    q, p = d_in // b, d_out // b
    blocks = w.reshape(b, q, b, p)
    idx = jnp.arange(b)
    return {"w": blocks[idx, :, idx, :]}  # (b, q, p)


def _adam_fit(w: jax.Array, spec: LinearSpec, key: jax.Array, *, steps: int = 300,
              lr: float = 3e-3) -> Params:
    """Generic gradient fit: min_params ‖w − W(params)‖²_F via Adam."""
    w = w.astype(jnp.float32)
    d_in = w.shape[0]
    eye = jnp.eye(d_in, dtype=jnp.float32)
    params = spec.init(key, dtype=jnp.float32)

    def loss_fn(p):
        approx = spec.apply(p, eye)  # (d_in, d_out)
        return jnp.mean((approx - w) ** 2)

    def adam_step(carry, k):
        p, m, v = carry
        g = jax.grad(loss_fn)(p)
        m = jax.tree.map(lambda a, b_: 0.9 * a + 0.1 * b_, m, g)
        v = jax.tree.map(lambda a, b_: 0.999 * a + 0.001 * b_ * b_, v, g)
        t = k.astype(jnp.float32) + 1.0
        mh = jax.tree.map(lambda a: a / (1 - 0.9**t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999**t), v)
        p = jax.tree.map(lambda a, mm, vv: a - lr * mm / (jnp.sqrt(vv) + 1e-8), p, mh, vh)
        return (p, m, v), loss_fn(p)

    zeros = jax.tree.map(jnp.zeros_like, params)
    (params, _, _), _ = jax.lax.scan(adam_step, (params, zeros, zeros), jnp.arange(steps))
    return params


def compress_linear(
    w: jax.Array,
    spec: LinearSpec,
    *,
    key: jax.Array | None = None,
    steps: int = 300,
) -> Params:
    """Compress dense ``w: (d_in, d_out)`` into the structure of ``spec``."""
    if key is None:
        key = jax.random.PRNGKey(0)
    kind = spec.kind
    if kind == "dense":
        return {"w": w}
    if kind == "blast":
        b, r = spec.meta["b"], spec.meta["r"]
        return fct.factorize_weight(w, b, r, steps=steps, key=key)
    if kind == "low_rank":
        return _svd_low_rank(w, spec.meta["rank"])
    if kind == "block_diag":
        return _block_diag_extract(w, spec.meta["b"])
    if kind in ("monarch", "pixelfly"):
        # no closed form for either support pattern → Adam fit of Frobenius
        out = _adam_fit(w, spec, key, steps=steps)
        return {k: v.astype(w.dtype) for k, v in out.items()}
    raise ValueError(kind)


def reconstruction_error(w: jax.Array, spec: LinearSpec, params: Params) -> float:
    """‖w − Ŵ‖_F / ‖w‖_F for any structure."""
    eye = jnp.eye(w.shape[0], dtype=jnp.float32)
    approx = spec.apply({k: v.astype(jnp.float32) for k, v in params.items()}, eye)
    w = w.astype(jnp.float32)
    return float(jnp.linalg.norm(approx - w) / jnp.linalg.norm(w))


def compress_tree(
    dense_weights: dict[str, jax.Array],
    specs: dict[str, LinearSpec],
    *,
    key: jax.Array | None = None,
    steps: int = 300,
    layer_axis: bool = False,
) -> dict[str, Params]:
    """Compress every named weight.  With ``layer_axis=True`` the weights are
    stacked over a leading scan-layer axis and compressed layer-by-layer."""
    if key is None:
        key = jax.random.PRNGKey(0)
    out: dict[str, Params] = {}
    for i, (name, w) in enumerate(sorted(dense_weights.items())):
        sub = jax.random.fold_in(key, i)
        spec = specs[name]
        if layer_axis:
            fn = lambda wl, k=sub, s=spec: compress_linear(wl, s, key=k, steps=steps)
            out[name] = jax.lax.map(fn, w)
        else:
            out[name] = compress_linear(w, spec, key=sub, steps=steps)
    return out
