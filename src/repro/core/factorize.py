"""BLAST factorization of pre-trained dense weights (paper §3.2, Alg. 2).

Given a dense ``A ∈ R^{m×n}``, find BLAST factors minimizing the blockwise
Frobenius loss (Eq. 4):

    ℓ(U, V, s) = Σ_ij ½‖A_ij − U_i diag(s_ij) V_jᵀ‖_F².

Two optimizers:
  * ``gd``      — alternating gradient descent (Eqs. 5–7); with
                  ``spectral_steps=True`` uses the Theorem-1 step sizes
                  (1/σ₁ of the relevant Gram matrices) which guarantee
                  monotone non-increase of the loss.
  * ``precgd``  — Algorithm 2: preconditioned GD with
                  P_U = (V̄ᵀV̄+δI)⁻¹, P_V = (ŪᵀŪ+δI)⁻¹,
                  P_s = ((UᵀU)⊙(VᵀV)+δI)⁻¹ and δ = δ₀·sqrt(ℓ).

All Gram/solve math is O(n·r² + r³) per step (paper's complexity claim);
the full m×n residual is never materialized.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.blast import BlastParams, check_divisible


class FactorizeResult(NamedTuple):
    params: BlastParams
    losses: jax.Array  # (steps,) loss before each update
    final_loss: jax.Array


def _block_view(A: jax.Array, b: int) -> jax.Array:
    """(m, n) → (b_i, b_j, p, q)."""
    m, n = A.shape
    p, q = check_divisible(m, n, b)
    return A.reshape(b, p, b, q).transpose(0, 2, 1, 3)


def _residual_loss(Ab, U, S, V):
    """Exact Eq. 4 loss Σ_ij ½‖A_ij − U_i diag(s_ij) V_jᵀ‖² (no cancellation).

    Cost O(mnr) — same order as the gradient einsums.
    """
    approx = jnp.einsum("ipr,ijr,jqr->ijpq", U, S, V)
    diff = Ab - approx
    return 0.5 * jnp.sum(diff * diff)


def _compute_T(Ab, U, V):
    """T_ij = diag(U_iᵀ A_ij V_j) ∈ R^r  (b, b, r)."""
    return jnp.einsum("ipr,ijpq,jqr->ijr", U, Ab, V)


@functools.partial(
    jax.jit,
    static_argnames=("b", "r", "steps", "precondition", "spectral_steps"),
)
def factorize(
    A: jax.Array,
    b: int,
    r: int,
    *,
    steps: int = 300,
    key: jax.Array | None = None,
    delta0: float = 0.1,
    eps: float = 1e-2,
    lr: float = 1.0,
    lr_end: float = 0.0,
    precondition: bool = True,
    spectral_steps: bool = False,
) -> FactorizeResult:
    """Factorize ``A`` into BLAST(b, r).  fp32 internally."""
    if key is None:
        key = jax.random.PRNGKey(0)
    A = A.astype(jnp.float32)
    m, n = A.shape
    p, q = check_divisible(m, n, b)
    Ab = _block_view(A, b)  # (b, b, p, q)
    a_sq = jnp.sum(A * A)

    ku, kv, ks = jax.random.split(key, 3)
    U0 = eps * jax.random.normal(ku, (b, p, r), dtype=jnp.float32)
    V0 = eps * jax.random.normal(kv, (b, q, r), dtype=jnp.float32)
    S0 = jax.random.uniform(ks, (b, b, r), dtype=jnp.float32)

    eye_r = jnp.eye(r, dtype=jnp.float32)

    def solve_psd(Mat, B):
        """B @ (Mat)⁻¹ for symmetric PSD Mat (batched over leading dims)."""
        return jnp.linalg.solve(Mat, jnp.swapaxes(B, -1, -2))

    def step(carry, k):
        U, V, S, loss = carry
        eta = lr + (lr_end - lr) * (k.astype(jnp.float32) / steps)
        # δ = δ₀·sqrt(ℓ) (Eq. 19), floored to keep the solves non-singular
        # once the residual is at fp32 noise level.
        delta = delta0 * jnp.sqrt(jnp.maximum(loss, 1e-12 * a_sq))

        # ---- U update:  G_i = U_i M_i − C_i,  M_i = V̄_iᵀV̄_i, C_i = A_i,*V̄_i
        VtV = jnp.einsum("jqr,jqt->jrt", V, V)  # (b, r, r)
        # M_i = Σ_j diag(s_ij) (V_jᵀV_j) diag(s_ij)
        M = jnp.einsum("ijr,jrt,ijt->irt", S, VtV, S)  # (b, r, r)
        # C_i = Σ_j A_ij V_j diag(s_ij)
        C = jnp.einsum("ijpq,jqr,ijr->ipr", Ab, V, S)  # (b, p, r)
        G_u = jnp.einsum("ipr,irt->ipt", U, M) - C
        if spectral_steps:
            sig = jnp.linalg.eigvalsh(M)[..., -1]  # σ₁ per block-row
            eta_u = 1.0 / jnp.maximum(sig, 1e-12)
            U = U - eta_u[:, None, None] * G_u
        elif precondition:
            upd = jnp.swapaxes(solve_psd(M + delta * eye_r, G_u), -1, -2)
            U = U - eta * upd
        else:
            U = U - eta * G_u

        # ---- V update (uses updated U):  N_j = Ū_jᵀŪ_j, D_j = A_*,jᵀŪ_j
        UtU = jnp.einsum("ipr,ipt->irt", U, U)  # (b, r, r)
        N = jnp.einsum("ijr,irt,ijt->jrt", S, UtU, S)  # (b, r, r)
        D = jnp.einsum("ijpq,ipr,ijr->jqr", Ab, U, S)  # (b, q, r)
        G_v = jnp.einsum("jqr,jrt->jqt", V, N) - D
        if spectral_steps:
            sig = jnp.linalg.eigvalsh(N)[..., -1]
            eta_v = 1.0 / jnp.maximum(sig, 1e-12)
            V = V - eta_v[:, None, None] * G_v
        elif precondition:
            upd = jnp.swapaxes(solve_psd(N + delta * eye_r, G_v), -1, -2)
            V = V - eta * upd
        else:
            V = V - eta * G_v

        # ---- s update (uses updated U, V):
        UtU = jnp.einsum("ipr,ipt->irt", U, U)
        VtV = jnp.einsum("jqr,jqt->jrt", V, V)
        T = _compute_T(Ab, U, V)  # (b, b, r)

        def s_row(S_i_T_i):
            S_i, T_i, UtU_i = S_i_T_i  # (b, r), (b, r), (r, r)
            W_i = UtU_i[None, :, :] * VtV  # (b, r, r)
            g = jnp.einsum("jrt,jt->jr", W_i, S_i) - T_i
            if spectral_steps:
                sig = jnp.linalg.eigvalsh(W_i)[..., -1]
                return S_i - g / jnp.maximum(sig, 1e-12)[:, None]
            if precondition:
                sol = jnp.linalg.solve(W_i + delta * eye_r, g[..., None])
                return S_i - eta * sol[..., 0]
            return S_i - eta * g

        S = jax.lax.map(s_row, (S, T, UtU))

        # ---- loss after the full (U, V, s) sweep
        new_loss = _residual_loss(Ab, U, S, V)
        return (U, V, S, new_loss), loss

    init_loss = 0.5 * a_sq  # tiny-init ⇒ ℓ ≈ ½‖A‖²
    (U, V, S, final_loss), losses = jax.lax.scan(
        step, (U0, V0, S0, init_loss), jnp.arange(steps)
    )
    return FactorizeResult(BlastParams(U=U, S=S, V=V), losses, final_loss)


def normalized_error(A: jax.Array, params: BlastParams) -> jax.Array:
    """‖A − Â‖_F / ‖A‖_F."""
    from repro.core.blast import to_dense

    A = A.astype(jnp.float32)
    diff = A - to_dense(params).astype(jnp.float32)
    return jnp.linalg.norm(diff) / jnp.linalg.norm(A)


def factorize_weight(w: jax.Array, b: int, r: int, **kw) -> dict[str, jax.Array]:
    """Factorize a layer weight ``w: (d_in, d_out)`` (A = wᵀ) → param dict."""
    res = factorize(w.T.astype(jnp.float32), b, r, **kw)
    return {
        "U": res.params.U.astype(w.dtype),
        "S": res.params.S.astype(w.dtype),
        "V": res.params.V.astype(w.dtype),
    }
