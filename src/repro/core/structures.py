"""Unified structured-linear interface: dense / low_rank / monarch /
block_diag / blast behind one spec, so every model layer is structure-
agnostic and the paper's baselines (§4) are first-class.

Each structure defines:
  * ``init(key, dtype)``   → params pytree (dict of arrays)
  * ``apply(params, x)``   → ``x: (..., d_in) → (..., d_out)``
  * ``quantize(params, bits)`` → params with per-block-int QArray leaves
  * ``apply_q(qparams, x)`` → same contract as ``apply`` on quantized params,
    with dequantization fused at the innermost matmul: weights enter the
    contraction as integer codes and the per-block scales multiply the
    *product*, never a materialized float weight tensor
  * ``num_params``, ``flops_per_token`` (multiplications, matching paper's
    FLOPs accounting which counts multiplications)
  * ``logical_axes``       → dict param-name → tuple of logical axis names,
    consumed by launch/sharding.py to build PartitionSpecs.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro import quant as qt
from repro.core import blast as blast_lib

Params = dict[str, jax.Array]

STRUCTURES = ("dense", "blast", "low_rank", "monarch", "block_diag",
              "pixelfly")


@dataclasses.dataclass(frozen=True)
class StructureConfig:
    """How to structure the linear layers of a model.

    kind:        one of STRUCTURES
    b:           number of blocks per axis (blast / monarch / block_diag)
    keep_ratio:  target params / dense params; used to solve ranks when an
                 explicit rank is not given.
    rank:        explicit rank override (blast r / low-rank t / monarch k)
    """

    kind: str = "dense"
    b: int = 16
    keep_ratio: float = 0.5
    rank: int | None = None
    # BLAST tensor-parallel scheme: "rank" (Megatron-2-layer analogue: shard
    # r, one output AR per linear) or "block" (shard the b block axis; stage
    # 1/3 run block-local and the cross-block coupling reshards via
    # all-to-all/reduce-scatter of the (tokens, b, r) intermediate).
    tp: str = "rank"

    def __post_init__(self):
        if self.kind not in STRUCTURES:
            raise ValueError(f"unknown structure kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    kind: str
    d_in: int
    d_out: int
    shapes: dict[str, tuple[int, ...]]
    logical_axes: dict[str, tuple[str | None, ...]]
    init: Callable[..., Params]
    apply: Callable[[Params, jax.Array], jax.Array]
    num_params: int
    flops_per_token: int
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)
    quantize: Callable[..., Params] = None
    apply_q: Callable[[Params, jax.Array], jax.Array] = None

    def abstract_params(self, dtype=jnp.float32) -> dict[str, jax.ShapeDtypeStruct]:
        return {k: jax.ShapeDtypeStruct(s, dtype) for k, s in self.shapes.items()}


def _pick_blocks(d_in: int, d_out: int, b: int) -> int:
    """Largest b' ≤ b dividing both dims (keeps configs robust to odd dims)."""
    bb = min(b, d_in, d_out)
    while bb > 1 and (d_in % bb or d_out % bb):
        bb -= 1
    return max(bb, 1)


def _block_quantizer(block_axes: dict[str, tuple[int, ...]]):
    """Build a ``quantize(params, bits)`` that maps each named param to a
    per-block QArray (params not listed — e.g. bias — pass through)."""
    def quantize(params: Params, bits: int = 8) -> Params:
        out: Params = {}
        for k, v in params.items():
            ba = block_axes.get(k)
            out[k] = v if ba is None else qt.quantize(v, bits=bits,
                                                      block_axes=ba)
        return out
    return quantize


def _iv(qa, dtype):
    """Integer codes of a QArray cast for the MXU contraction (int8 values
    are exactly representable in bf16/f32 — the cast is lossless)."""
    return qt.int_values(qa).astype(dtype)


# -- dense ------------------------------------------------------------------


def _dense_spec(d_in: int, d_out: int, cfg: StructureConfig) -> LinearSpec:
    shapes = {"w": (d_in, d_out)}

    def init(key, dtype=jnp.float32, scale=None):
        std = scale if scale is not None else 1.0 / math.sqrt(d_in)
        return {"w": (std * jax.random.normal(key, (d_in, d_out))).astype(dtype)}

    def apply(params, x):
        return x @ params["w"]

    def apply_q(params, x):
        w = params["w"]
        y = x @ _iv(w, x.dtype)                 # int codes on the MXU
        return (y * w.scale[0]).astype(x.dtype)  # dequant fused post-matmul

    return LinearSpec(
        kind="dense", d_in=d_in, d_out=d_out, shapes=shapes,
        logical_axes={"w": ("in", "out")},
        init=init, apply=apply,
        num_params=d_in * d_out, flops_per_token=d_in * d_out,
        quantize=_block_quantizer({"w": (0,)}),  # per-output-channel scales
        apply_q=apply_q,
    )


# -- blast ------------------------------------------------------------------


def _blast_spec(d_in: int, d_out: int, cfg: StructureConfig) -> LinearSpec:
    m, n = d_out, d_in
    b = _pick_blocks(n, m, cfg.b)
    r = cfg.rank or blast_lib.rank_for_compression(m, n, b, cfg.keep_ratio,
                                                   align=16)
    p, q = m // b, n // b

    def init(key, dtype=jnp.float32, scale=None):
        params = blast_lib.init(key, m, n, b, r, dtype=dtype)
        return {"U": params.U, "S": params.S, "V": params.V}

    def apply(params, x):
        return blast_lib.matmul(x, blast_lib.BlastParams(params["U"], params["S"], params["V"]))

    def apply_q(params, x):
        """Alg. 1 with per-block int8/int4 factors; each stage dequantizes by
        a scalar-per-block multiply on the stage *output* (XLA mirror of the
        fused Pallas kernel in kernels/blast_matmul.py).  With the process-
        wide activation mode set to "int8" (W8A8/W4A8), x is quantized per
        token and stage 1 contracts int8 codes in int32, dequantizing once
        with the fused ``sx · sv_j`` product — mirroring the integer
        kernels."""
        Uq, Sq, Vq = params["U"], params["S"], params["V"]
        lead = x.shape[:-1]
        if activations_mode() == "int8":
            xq, sx = qt.quantize_act(x)
            z = jnp.einsum("...jq,jqr->...jr", xq.reshape(*lead, b, q),
                           qt.int_values(Vq),
                           preferred_element_type=jnp.int32)
            z = (z.astype(jnp.float32) * sx[..., None]    # (..., 1, 1)
                 * Vq.scale[:, :, 0])                     # (b, 1) per block
        else:
            xb = x.reshape(*lead, b, q)
            z = jnp.einsum("...jq,jqr->...jr", xb, _iv(Vq, x.dtype))
            z = z.astype(jnp.float32) * Vq.scale[:, :, 0]
        s = qt.int_values(Sq).astype(jnp.float32) * Sq.scale  # in-register
        w = jnp.einsum("...jr,ijr->...ir", z, s)
        y = jnp.einsum("...ir,ipr->...ip", w, _iv(Uq, jnp.float32))
        y = y * Uq.scale[:, :, 0]
        return y.reshape(*lead, m).astype(x.dtype)

    if cfg.tp == "block":
        axes = {"U": ("blocks_tp", "out_block", None),
                "S": ("blocks_tp", "blocks_j", None),
                "V": ("blocks_tp", "in_block", None)}
    else:
        axes = {"U": ("blocks", "out_block", "rank"),
                "S": ("blocks", "blocks_j", "rank"),
                "V": ("blocks", "in_block", "rank")}
    return LinearSpec(
        kind="blast", d_in=d_in, d_out=d_out,
        shapes={"U": (b, p, r), "S": (b, b, r), "V": (b, q, r)},
        logical_axes=axes,
        init=init, apply=apply,
        num_params=blast_lib.num_params(m, n, b, r),
        flops_per_token=blast_lib.matvec_flops(m, n, b, r),
        meta={"b": b, "r": r},
        # one scale per U_i / V_j factor block, one per s_ij coupling vector
        quantize=_block_quantizer({"U": (1, 2), "S": (2,), "V": (1, 2)}),
        apply_q=apply_q,
    )


# -- low rank ---------------------------------------------------------------


def _low_rank_spec(d_in: int, d_out: int, cfg: StructureConfig) -> LinearSpec:
    t = cfg.rank or max(1, int(cfg.keep_ratio * d_in * d_out / (d_in + d_out)))
    if t >= 32:
        t = (t // 16) * 16  # TP-shardable rank

    def init(key, dtype=jnp.float32, scale=None):
        k1, k2 = jax.random.split(key)
        s1 = 1.0 / math.sqrt(d_in)
        s2 = 1.0 / math.sqrt(t)
        return {
            "w_down": (s1 * jax.random.normal(k1, (d_in, t))).astype(dtype),
            "w_up": (s2 * jax.random.normal(k2, (t, d_out))).astype(dtype),
        }

    def apply(params, x):
        return (x @ params["w_down"]) @ params["w_up"]

    def apply_q(params, x):
        d, u = params["w_down"], params["w_up"]
        h = (x @ _iv(d, x.dtype)) * d.scale[0]
        y = (h.astype(x.dtype) @ _iv(u, x.dtype)) * u.scale[0]
        return y.astype(x.dtype)

    return LinearSpec(
        kind="low_rank", d_in=d_in, d_out=d_out,
        shapes={"w_down": (d_in, t), "w_up": (t, d_out)},
        logical_axes={"w_down": ("in", "rank"), "w_up": ("rank", "out")},
        init=init, apply=apply,
        num_params=t * (d_in + d_out), flops_per_token=t * (d_in + d_out),
        meta={"rank": t},
        quantize=_block_quantizer({"w_down": (0,), "w_up": (0,)}),
        apply_q=apply_q,
    )


# -- monarch ----------------------------------------------------------------


def _monarch_spec(d_in: int, d_out: int, cfg: StructureConfig) -> LinearSpec:
    """Monarch/BLR: y = reshape(einsum(R, transpose(einsum(L, x)))).

    L: (b, q, k) block-diagonal over input blocks; permute; R: (k, b, c)
    block-diagonal over the k axis, with c == b so that out = (c, k) → m.
    k is solved from the parameter budget; requires k·b == d_out.
    """
    m, n = d_out, d_in
    b = _pick_blocks(n, m, cfg.b)
    q = n // b
    c = b
    k = m // c  # out = (c, k) flatten → exact-monarch mid width
    if cfg.rank is not None:
        k = cfg.rank
    else:
        # Budget: params = b·q·k + k·b·c ≤ keep·m·n  → k ≤ keep·m·n / (b(q+c))
        k_budget = int(cfg.keep_ratio * m * n / (b * (q + c)))
        k = max(1, min(k, k_budget))
    # If k no longer divides m we fall back to rectangular R: (k, b, m//b) and
    # flatten as (b_out, p) with p = m//b — the generalized BLR form.
    exact = (k * c == m)
    p = m // b

    def init(key, dtype=jnp.float32, scale=None):
        k1, k2 = jax.random.split(key)
        s1 = 1.0 / math.sqrt(q)
        s2 = 1.0 / math.sqrt(k)
        L = (s1 * jax.random.normal(k1, (b, q, k))).astype(dtype)
        R = (s2 * jax.random.normal(k2, (k, b, c if exact else p))).astype(dtype)
        return {"L": L, "R": R}

    def apply(params, x):
        lead = x.shape[:-1]
        xb = x.reshape(*lead, b, q)
        u = jnp.einsum("...bq,bqk->...bk", xb, params["L"])
        if exact:
            y = jnp.einsum("...bk,kbc->...ck", u, params["R"])  # (..., c, k)
            return y.reshape(*lead, m)
        y = jnp.einsum("...bk,kbp->...bp", u, params["R"])
        return y.reshape(*lead, m)

    def apply_q(params, x):
        Lq, Rq = params["L"], params["R"]
        lead = x.shape[:-1]
        xb = x.reshape(*lead, b, q)
        u = jnp.einsum("...bq,bqk->...bk", xb, _iv(Lq, x.dtype))
        u = u.astype(jnp.float32) * Lq.scale[:, :, 0]        # (b, 1)
        if exact:
            # contraction over b → R's scale must be constant over b: one
            # scale per k-indexed (b, c) block, applied on the k output axis
            y = jnp.einsum("...bk,kbc->...ck", u, _iv(Rq, jnp.float32))
            y = y * Rq.scale[:, 0, 0]                        # (k,)
        else:
            y = jnp.einsum("...bk,kbp->...bp", u, _iv(Rq, jnp.float32))
            y = y * Rq.scale[0, :, :]                        # (b, 1)
        return y.reshape(*lead, m).astype(x.dtype)

    n_params = b * q * k + k * b * (c if exact else p)
    return LinearSpec(
        kind="monarch", d_in=d_in, d_out=d_out,
        shapes={"L": (b, q, k), "R": (k, b, c if exact else p)},
        logical_axes={"L": ("blocks", "in_block", "rank"),
                      "R": ("rank", "blocks", "out_block")},
        init=init, apply=apply,
        num_params=n_params, flops_per_token=n_params,
        meta={"b": b, "k": k, "exact": exact},
        quantize=_block_quantizer(
            {"L": (1, 2), "R": (1, 2) if exact else (0, 2)}),
        apply_q=apply_q,
    )


# -- block diagonal ----------------------------------------------------------


def _block_diag_spec(d_in: int, d_out: int, cfg: StructureConfig) -> LinearSpec:
    # Budget: params = m·n/b → choose the smallest b' ≥ cfg.b meeting keep.
    b = _pick_blocks(d_in, d_out, cfg.b)
    if cfg.rank is None and cfg.keep_ratio < 1.0 / b:
        bb = math.ceil(1.0 / cfg.keep_ratio)
        b = _pick_blocks(d_in, d_out, max(bb, b))
    q, p = d_in // b, d_out // b

    def init(key, dtype=jnp.float32, scale=None):
        std = 1.0 / math.sqrt(q)
        return {"w": (std * jax.random.normal(key, (b, q, p))).astype(dtype)}

    def apply(params, x):
        lead = x.shape[:-1]
        xb = x.reshape(*lead, b, q)
        y = jnp.einsum("...bq,bqp->...bp", xb, params["w"])
        return y.reshape(*lead, d_out)

    def apply_q(params, x):
        w = params["w"]
        lead = x.shape[:-1]
        xb = x.reshape(*lead, b, q)
        y = jnp.einsum("...bq,bqp->...bp", xb, _iv(w, x.dtype))
        y = y.astype(jnp.float32) * w.scale[:, :, 0]         # (b, 1)
        return y.reshape(*lead, d_out).astype(x.dtype)

    return LinearSpec(
        kind="block_diag", d_in=d_in, d_out=d_out,
        shapes={"w": (b, q, p)},
        logical_axes={"w": ("blocks", "in_block", "out_block")},
        init=init, apply=apply,
        num_params=b * q * p, flops_per_token=b * q * p,
        meta={"b": b},
        quantize=_block_quantizer({"w": (1, 2)}),
        apply_q=apply_q,
    )


# -- pixelfly (block-sparse butterfly + low-rank, Chen et al. 2022) ----------


def _pixelfly_blocks(b: int) -> list[tuple[int, int]]:
    """Flat block-butterfly support: block (i, j) is live iff i == j or
    |i − j| is a power of two — the flattened butterfly connectivity used
    by Pixelated Butterfly's block-sparse component."""
    live = []
    for i in range(b):
        for j in range(b):
            d = abs(i - j)
            if d == 0 or (d & (d - 1)) == 0:
                live.append((i, j))
    return live


def _pixelfly_spec(d_in: int, d_out: int, cfg: StructureConfig) -> LinearSpec:
    """Pixelfly ≈ block-sparse butterfly W_s (+ optional low-rank W_lr).

    The paper evaluates Pixelfly as its block-sparse baseline (§4.1).  We
    implement the flat block-butterfly support with dense resident blocks —
    a gather → batched-GEMM → scatter-add chain (no zero padding), with the
    residual low-rank term solved from the remaining parameter budget."""
    b = _pick_blocks(d_in, d_out, cfg.b)
    q, p = d_in // b, d_out // b
    live = _pixelfly_blocks(b)
    nnz = len(live)
    sparse_params = nnz * q * p
    budget = cfg.keep_ratio * d_in * d_out
    t = max(0, int((budget - sparse_params) // (d_in + d_out)))
    if t >= 32:
        t = (t // 16) * 16
    rows = jnp.array([i for i, _ in live], jnp.int32)
    cols = jnp.array([j for _, j in live], jnp.int32)

    def init(key, dtype=jnp.float32, scale=None):
        k1, k2, k3 = jax.random.split(key, 3)
        fan_in = q * sum(1 for _, j in live)  # loose bound; per-row varies
        std = 1.0 / math.sqrt(max(q * (2 * int(math.log2(b)) + 1 if b > 1
                                       else 1), 1))
        params = {"w": (std * jax.random.normal(k1, (nnz, q, p))).astype(dtype)}
        if t:
            params["w_down"] = ((1.0 / math.sqrt(d_in))
                                * jax.random.normal(k2, (d_in, t))).astype(dtype)
            params["w_up"] = ((1.0 / math.sqrt(max(t, 1)))
                              * jax.random.normal(k3, (t, d_out))).astype(dtype)
        return params

    def apply(params, x):
        lead = x.shape[:-1]
        xb = x.reshape(*lead, b, q)
        xg = jnp.take(xb, cols, axis=-2)                 # (..., nnz, q)
        yb = jnp.einsum("...eq,eqp->...ep", xg, params["w"])
        y = jnp.zeros((*lead, b, p), yb.dtype).at[..., rows, :].add(yb)
        y = y.reshape(*lead, b * p)
        if "w_down" in params:
            y = y + (x @ params["w_down"]) @ params["w_up"]
        return y

    def apply_q(params, x):
        w = params["w"]
        lead = x.shape[:-1]
        xb = x.reshape(*lead, b, q)
        xg = jnp.take(xb, cols, axis=-2)
        yb = jnp.einsum("...eq,eqp->...ep", xg, _iv(w, x.dtype))
        yb = yb.astype(jnp.float32) * w.scale[:, :, 0]       # (nnz, 1)
        y = jnp.zeros((*lead, b, p), yb.dtype).at[..., rows, :].add(yb)
        y = y.reshape(*lead, b * p)
        if "w_down" in params:
            d, u = params["w_down"], params["w_up"]
            h = (x @ _iv(d, x.dtype)) * d.scale[0]
            y = y + (h.astype(x.dtype) @ _iv(u, x.dtype)) * u.scale[0]
        return y.astype(x.dtype)

    shapes = {"w": (nnz, q, p)}
    axes = {"w": ("blocks", "in_block", "out_block")}
    qaxes = {"w": (1, 2)}
    if t:
        shapes.update(w_down=(d_in, t), w_up=(t, d_out))
        axes.update(w_down=("in", "rank"), w_up=("rank", "out"))
        qaxes.update(w_down=(0,), w_up=(0,))
    n_params = sparse_params + t * (d_in + d_out)
    return LinearSpec(
        kind="pixelfly", d_in=d_in, d_out=d_out, shapes=shapes,
        logical_axes=axes, init=init, apply=apply,
        num_params=n_params, flops_per_token=n_params,
        meta={"b": b, "nnz": nnz, "rank": t},
        quantize=_block_quantizer(qaxes),
        apply_q=apply_q,
    )


_MAKERS = {
    "dense": _dense_spec,
    "blast": _blast_spec,
    "low_rank": _low_rank_spec,
    "monarch": _monarch_spec,
    "block_diag": _block_diag_spec,
    "pixelfly": _pixelfly_spec,
}


def make_linear(d_in: int, d_out: int, structure: StructureConfig | None = None,
                *, structured: bool = True) -> LinearSpec:
    """Build a linear spec. ``structured=False`` forces dense (e.g. router,
    norm-adjacent projections the paper keeps dense)."""
    cfg = structure or StructureConfig()
    if not structured:
        cfg = StructureConfig(kind="dense")
    return _MAKERS[cfg.kind](d_in, d_out, cfg)


# ---------------------------------------------------------------------------
# Nested-rank truncation: a rank-r BLAST factor set contains every lower-rank
# model for free — dropping trailing components of U/S/V (or low-rank
# w_down/w_up columns) yields a cheaper model sharing storage with the full
# one.  This is the draft side of self-speculative decoding (serve/engine.py):
# the draft and the verifier are the SAME weights at two ranks, so the only
# new serving state is the per-layer truncation plan.
# ---------------------------------------------------------------------------


_RANK_AXES = {"blast": {"U": 2, "S": 2, "V": 2},
              "low_rank": {"w_down": 1, "w_up": 0}}


def rank_kind(params: Params) -> str | None:
    """'blast' | 'low_rank' for a rank-bearing linear's param dict, else None
    (dense / monarch / block_diag / pixelfly pass truncation through).
    Key-based so it works on any storage format (float / int8 / packed-int4
    QArrays) and under vmap (stacked MoE experts, scanned layer cycles)."""
    if not isinstance(params, dict):
        return None
    core = set(params) - {"bias"}
    if core == {"U", "S", "V"}:
        return "blast"
    if core == {"w_down", "w_up"}:
        return "low_rank"
    return None


def linear_rank(params: Params) -> int | None:
    """Static rank of a rank-bearing linear (QArray.shape reports the
    logical extent for nibble-packed int4)."""
    kind = rank_kind(params)
    if kind is None:
        return None
    return int(params["U" if kind == "blast" else "w_down"].shape[-1])


def _as_f32(a) -> jax.Array:
    return qt.dequantize(a) if qt.is_qarray(a) else a.astype(jnp.float32)


def rank_spectrum(params: Params) -> jax.Array | None:
    """Per-component energy e_rho — the exact squared-Frobenius contribution
    of rank component rho to the dense matrix (block rows/cols are disjoint,
    so contributions add):

      blast:    e_rho = sum_ij S[i,j,rho]^2 * |U[i,:,rho]|^2 * |V[j,:,rho]|^2
      low_rank: e_t   = |w_down[:,t]|^2 * |w_up[t,:]|^2

    Quantized params are dequantized first.  Returns None for kinds with no
    rank axis."""
    kind = rank_kind(params)
    if kind is None:
        return None
    if kind == "blast":
        U, S, V = (_as_f32(params[k]) for k in ("U", "S", "V"))
        su = jnp.sum(U * U, axis=1)                      # (b, r)
        sv = jnp.sum(V * V, axis=1)                      # (b, r)
        return jnp.einsum("ijr,ir,jr->r", S * S, su, sv)
    d, u = _as_f32(params["w_down"]), _as_f32(params["w_up"])
    return jnp.sum(d * d, axis=0) * jnp.sum(u * u, axis=1)


def _gather_rank(arr: jax.Array, idx: jax.Array, axis: int,
                 full: int) -> jax.Array:
    """Gather rank components along ``axis``; axes without the full rank
    extent (broadcast / per-block scales) pass through untouched."""
    if arr.shape[axis] != full:
        return arr
    return jnp.take(arr, idx, axis=axis)


def _take_rank(a, idx: jax.Array, axis: int, full: int):
    """Rank-gather one factor, preserving its storage format.

    int8 QArrays gather codes; their per-block scales gather only if the
    rank axis has full extent (blast block scales are (b,1,1)/(b,b,1) — no
    rank extent — and stay exact: the surviving codes decode with the same
    scale as before).  Packed int4 with the rank on the packed (last) axis
    unpacks, gathers, and repacks — a bit-exact roundtrip."""
    if not qt.is_qarray(a):
        return _gather_rank(a, idx, axis, full)
    scale = _gather_rank(a.scale, idx, axis, full)
    if a.bits == 4 and axis == a.q.ndim - 1:
        v = jnp.take(qt.int_values(a), idx, axis=axis)
        return qt.QArray(qt.pack_int4(v), scale, bits=4,
                         last_dim=int(idx.shape[0]))
    return qt.QArray(_gather_rank(a.q, idx, axis, full), scale, bits=a.bits,
                     last_dim=a.last_dim)


def truncate_rank(params: Params, r_prime: int) -> Params:
    """Truncate a rank-bearing linear to its ``r_prime`` highest-energy
    components; non-rank-bearing kinds (and ``r_prime >= r``) return the
    params unchanged.

    Kept indices are sorted ascending, so full-rank truncation is the
    identity and — because the rank contraction is permutation-invariant —
    the truncated ``apply`` equals the full ``apply`` with the dropped
    components zeroed.  Works for float, int8 and packed-int4 storage; the
    result is a normal param dict the unmodified apply paths consume (they
    read ranks from array shapes, not specs)."""
    kind = rank_kind(params)
    if kind is None:
        return params
    full = linear_rank(params)
    r_prime = max(1, min(int(r_prime), full))
    if r_prime == full:
        return dict(params)
    idx = jnp.sort(jax.lax.top_k(rank_spectrum(params), r_prime)[1])
    axes = _RANK_AXES[kind]
    return {k: (_take_rank(v, idx, axes[k], full) if k in axes else v)
            for k, v in params.items()}


# ---------------------------------------------------------------------------
# Grouped dispatch: run a layer's shape-congruent same-input projections
# (gate+up, MLA a-projections, RG-LRU input/gate branches, …) as ONE matmul
# launch instead of one per projection.  At decode time every launch
# re-streams its factors and pads T=1 to a sublane tile, so collapsing a
# bundle is a direct hot-path win; the Pallas side is
# ``kernels/blast_matmul.py``'s grouped kernels (leading G grid dim, one
# shared x-tile), the XLA/GSPMD side is the batched einsum chain below.
# ---------------------------------------------------------------------------


_GROUPING = [True]     # process-wide toggle (trace-time; see grouping())
_DISPATCHES = [0]      # structured-matmul dispatch counter (trace-time)
_STACKS = [0]          # per-step factor-stacking counter (trace-time)
_ACT_MODE = ["none"]   # activation storage: "none" | "int8" (trace-time)
_TP_MESH = [None]      # (mesh, axis) routing Pallas applies under shard_map


def set_tp_mesh(mesh, axis: str = "model") -> None:
    """Route ``group_apply(use_pallas=True)`` through the shard_map TP
    wrappers (``kernels/ops.py::blast_matmul_grouped*_tp``): each device
    contracts its rank shard with its own grouped launch and the stage-3
    output is psum'd.  Trace-time process toggle like ``set_activations`` —
    the engine flips it at build when its model carries an active mesh with
    tp > 1; ``set_tp_mesh(None)`` restores the single-launch path.  The XLA
    einsum path (``use_pallas=False``) is unaffected: GSPMD realizes the
    same rank-parallel scheme from the factor shardings directly."""
    _TP_MESH[0] = None if mesh is None else (mesh, axis)


def tp_mesh():
    return _TP_MESH[0]


@contextlib.contextmanager
def tp_sharding(mesh, axis: str = "model"):
    """Temporarily route Pallas grouped applies under shard_map (trace-time
    toggle, same contract as ``grouping``)."""
    prev = _TP_MESH[0]
    set_tp_mesh(mesh, axis)
    try:
        yield
    finally:
        _TP_MESH[0] = prev


def set_activations(mode: str) -> None:
    """Select the activation storage for quantized blast applies process-
    wide ("none" float activations, "int8" per-token integer contractions —
    the W8A8/W4A8 paths).  Trace-time like ``grouping``: it bakes into
    programs compiled afterwards; the engine sets it at build from
    ``QuantConfig.activations``."""
    if mode not in ("none", "int8"):
        raise ValueError(f"activation mode must be 'none'|'int8', got {mode}")
    _ACT_MODE[0] = mode


def activations_mode() -> str:
    return _ACT_MODE[0]


@contextlib.contextmanager
def activations(mode: str):
    """Temporarily select the activation storage (trace-time toggle, same
    contract as ``grouping``)."""
    prev = _ACT_MODE[0]
    set_activations(mode)
    try:
        yield
    finally:
        _ACT_MODE[0] = prev


def row_health(logits, absmax: float | None = None):
    """Per-row numeric health of a logits block: (B,) bool, True where the
    row is finite everywhere and (optionally) |logit| ≤ ``absmax``.

    This is the guarded-apply check the serving layer runs after every
    jitted step — low-precision paths (int8 activation rounding, truncated
    draft ranks) are exactly where overflow/NaN faults originate, and one
    cheap reduction here is what lets a poisoned row degrade gracefully
    instead of wedging the batch.  Reduces over every non-batch axis, so it
    accepts (B, V), (B, C, V) and any wider logits layout."""
    axes = tuple(range(1, logits.ndim))
    finite = jnp.isfinite(logits)
    ok = finite.all(axis=axes)
    if absmax is not None:
        # mask non-finite entries out of the max so inf does not shadow the
        # finiteness bit with a second (redundant) trip reason
        mag = jnp.abs(jnp.where(finite, logits, 0.0)).max(axis=axes)
        ok = ok & (mag <= absmax)
    return ok


def record_dispatch(n: int = 1) -> None:
    """Count one projection-matmul dispatch (== one kernel launch on the
    Pallas path).  Incremented at trace/eager-apply time — measure per-step
    launch counts by applying an *unrolled* model eagerly (see
    benchmarks/serving_throughput.py)."""
    _DISPATCHES[0] += n


def dispatch_count() -> int:
    return _DISPATCHES[0]


def reset_dispatch_count() -> None:
    _DISPATCHES[0] = 0


def record_stack(n: int = 1) -> None:
    """Count one in-step bundle stack (the pad+concat of a grouped bundle's
    member factors).  Zero per step once the caller supplies pre-stacked
    ``GroupBundle``s (``prestack`` / ``Engine(prestack=True)``) — measured
    the same way as dispatches: unrolled model, eager apply."""
    _STACKS[0] += n


def stack_count() -> int:
    return _STACKS[0]


def reset_stack_count() -> None:
    _STACKS[0] = 0


def grouping_enabled() -> bool:
    return _GROUPING[0]


@contextlib.contextmanager
def grouping(enabled: bool):
    """Temporarily toggle the grouped fast path (affects only code traced
    inside the context — useful for grouped-vs-loop comparisons)."""
    prev = _GROUPING[0]
    _GROUPING[0] = bool(enabled)
    try:
        yield
    finally:
        _GROUPING[0] = prev


def _storage(params: Params) -> str:
    """'float' | 'int8' | 'int4' | 'mixed' for one linear's param dict.
    The bias (always float, added post-matmul and stripped before
    ``group_apply``) does not participate in the classification."""
    kinds = set()
    for k, v in params.items():
        if k == "bias":
            continue
        kinds.add(f"int{v.bits}" if qt.is_qarray(v) else "float")
    return kinds.pop() if len(kinds) == 1 else "mixed"


def group_plan(specs: Sequence[LinearSpec],
               params_list: Sequence[Params]) -> dict | None:
    """Congruence check: can these same-input linears run as one grouped
    launch?  Eligible: ≥2 members, all the same structure kind out of
    {blast, dense, block_diag}, same d_in (they share x), same block count
    b for the blocked kinds, and uniform storage — all-float, all-int8, or
    all-int4.  int4 blast bundles stack their nibble-packed bytes *packed*
    and run the grouped q4 kernel (one launch, half the factor reads);
    int4 dense / block_diag bundles unpack to int8 codes at stack time
    (once, at prestack) and ride the int8 grouped path.  d_out / rank
    may differ: members are zero-padded to the group max, which is exact
    (padded rows/ranks — and for int4 padded zero bytes, i.e. zero codes —
    contribute nothing and are sliced off).  Returns the stacking plan, or
    None → caller falls back to the per-projection loop.
    """
    if not _GROUPING[0] or len(specs) < 2:
        return None
    kind = specs[0].kind
    if kind not in ("blast", "dense", "block_diag"):
        return None
    if any(s.kind != kind or s.d_in != specs[0].d_in for s in specs):
        return None
    storage = _storage(params_list[0])
    if storage not in ("float", "int8", "int4"):
        return None
    if any(_storage(p) != storage for p in params_list[1:]):
        return None
    plan = {"kind": kind, "storage": storage, "d_in": specs[0].d_in,
            "d_outs": [s.d_out for s in specs]}
    if kind in ("blast", "block_diag"):
        b = specs[0].meta["b"]
        if any(s.meta["b"] != b for s in specs):
            return None
        plan["b"] = b
        plan["p"] = max(s.d_out // b for s in specs)
        if kind == "blast":
            # rank from the actual factor arrays, not the spec: truncated
            # draft params (truncate_rank) carry r' < spec.meta["r"], and
            # padding them back to spec rank would undo the truncation
            # (QArray.shape reports the logical rank for packed storage)
            plan["r"] = max(int(p["U"].shape[-1]) for p in params_list)
    return plan


def _pad_to(a: jax.Array, axis: int, size: int) -> jax.Array:
    if a.shape[axis] == size:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, size - a.shape[axis])
    return jnp.pad(a, pad)


def _split_group(y: jax.Array, plan: dict, lead: tuple[int, ...],
                 dtype) -> list[jax.Array]:
    """(G, ..., m̂) grouped output → per-member (..., d_out) slices."""
    outs = []
    b = plan.get("b")
    for g, d_out in enumerate(plan["d_outs"]):
        yg = y[g]
        if b is not None:
            p_hat = yg.shape[-1] // b
            p_g = d_out // b
            if p_g != p_hat:
                yg = yg.reshape(*lead, b, p_hat)[..., :p_g]
            yg = yg.reshape(*lead, d_out)
        else:
            yg = yg[..., :d_out]
        outs.append(yg.astype(dtype))
    return outs


def _stack_group(params_list: Sequence[Params], plan: dict) -> dict:
    """Pad + stack a grouped bundle's member factors into the batched arrays
    ``group_apply`` contracts against.  This is per-step work when the
    caller passes raw per-member params; ``prestack`` runs it once at engine
    load and carries the result in a ``GroupBundle`` so the step skips it
    entirely — the ``record_stack`` counter is how tests pin that down."""
    record_stack(1)
    kind, storage = plan["kind"], plan["storage"]
    if kind == "dense":
        m_hat = max(plan["d_outs"])
        if storage == "float":
            return {"W": jnp.stack([_pad_to(p["w"], 1, m_hat)
                                    for p in params_list])}
        return {"W": jnp.stack([_pad_to(qt.int_values(p["w"]), 1, m_hat)
                                for p in params_list]),
                "sc": jnp.stack([_pad_to(p["w"].scale[0], 0, m_hat)
                                 for p in params_list])}       # (G, m̂)
    if kind == "block_diag":
        p_hat = plan["p"]
        if storage == "float":
            return {"W": jnp.stack([_pad_to(p["w"], 2, p_hat)
                                    for p in params_list])}
        return {"W": jnp.stack([_pad_to(qt.int_values(p["w"]), 2, p_hat)
                                for p in params_list]),
                "sw": jnp.stack([p["w"].scale[:, 0, 0]
                                 for p in params_list])}       # (G, b)

    b, p_hat, r_hat = plan["b"], plan["p"], plan["r"]
    q = plan["d_in"] // b
    packed = storage == "int4"
    # int4 members stack *packed*: the byte axis pads with zero bytes (two
    # zero codes each), so the grouped q4 kernel's plane unpack sees exact
    # zero-rank padding and the operands never materialize as int8
    r_tgt = (r_hat + 1) // 2 if packed else r_hat

    def stack(name: str, width: int):
        """Pad each member's factor to (b, width, r̂) and stack over G."""
        outs = []
        for pp in params_list:
            a = pp[name]
            if qt.is_qarray(a):
                a = a.q if packed else qt.int_values(a)
            outs.append(_pad_to(_pad_to(a, 2, r_tgt), 1, width))
        return jnp.stack(outs)

    out = {"U": stack("U", p_hat), "S": stack("S", b), "V": stack("V", q)}
    if storage in ("int8", "int4"):
        out["su"] = jnp.stack([pp["U"].scale.reshape(b)
                               for pp in params_list])
        out["ss"] = jnp.stack([pp["S"].scale.reshape(b, b)
                               for pp in params_list])
        out["sv"] = jnp.stack([pp["V"].scale.reshape(b)
                               for pp in params_list])
    return out


def _plan_items(plan: dict) -> tuple:
    """Hashable (pytree-aux-safe) encoding of a group plan."""
    return tuple(sorted((k, tuple(v) if isinstance(v, list) else v)
                        for k, v in plan.items()))


@jax.tree_util.register_pytree_node_class
class GroupBundle:
    """Pre-stacked grouped-projection factors, built once at engine load
    (``prestack``) instead of on every step.  A pytree: children are the
    stacked arrays, aux data is the (static, hashable) plan — so a bundle
    rides inside a param dict through jit/vmap, and a stale bundle (plan
    mismatch after re-quantization or truncation) is simply ignored by
    ``linear_group_apply``."""

    def __init__(self, arrays: dict, plan_items: tuple):
        self.arrays = dict(arrays)
        self.plan_items = plan_items

    @property
    def plan(self) -> dict:
        d = dict(self.plan_items)
        d["d_outs"] = list(d["d_outs"])
        return d

    def tree_flatten(self):
        names = tuple(sorted(self.arrays))
        return tuple(self.arrays[n] for n in names), (names, self.plan_items)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, plan_items = aux
        return cls(dict(zip(names, children)), plan_items)


def prestack(specs: Sequence[LinearSpec],
             params_list: Sequence[Params]) -> GroupBundle | None:
    """Build a ``GroupBundle`` for one projection bundle, or None when the
    bundle is not groupable (mixed storage / grouping disabled / ineligible
    kind) — then the per-step path is the fallback loop and there is
    nothing to pre-stack.  int4 blast bundles pre-stack their *packed*
    bytes.  Load-time stacking is excluded from the per-step counter."""
    plan = group_plan(specs, params_list)
    if plan is None:
        return None
    core = [{k: v for k, v in p.items() if k != "bias"} for p in params_list]
    before = _STACKS[0]
    arrays = _stack_group(core, plan)
    _STACKS[0] = before
    return GroupBundle(arrays, _plan_items(plan))


def group_apply(specs: Sequence[LinearSpec], params_list: Sequence[Params],
                x: jax.Array, *, plan: dict | None = None,
                use_pallas: bool = False,
                stacked: dict | None = None) -> list[jax.Array]:
    """Apply G congruent same-input linears as ONE grouped matmul.

    ``plan`` must come from ``group_plan`` (callers usually go through
    ``models/layers.py::linear_group_apply``, which handles the fallback).
    The default path is the stacked einsum chain (XLA/GSPMD, mirroring the
    per-structure ``apply``/``apply_q``); ``use_pallas=True`` dispatches the
    fused grouped Pallas kernel instead (shard_map-per-device execution).
    Counts as a single dispatch.

    ``stacked``: pre-stacked factor arrays (a ``GroupBundle.arrays`` built
    by ``prestack`` at load).  When omitted the member factors are padded
    and stacked inside the step — XLA fuses the concatenate into the
    consumer on the shapes we run, but the pre-stacked path skips the work
    outright (and the per-step ``stack_count`` stays zero)."""
    if plan is None:
        plan = group_plan(specs, params_list)
    assert plan is not None, "group_apply requires a valid group_plan"
    record_dispatch(1)
    st = stacked if stacked is not None else _stack_group(params_list, plan)
    lead = x.shape[:-1]
    G = len(specs)
    kind, storage = plan["kind"], plan["storage"]

    if kind == "dense":
        m_hat = max(plan["d_outs"])
        if storage == "float":
            y = jnp.einsum("...n,gnm->g...m", x, st["W"])
        else:
            y = jnp.einsum("...n,gnm->g...m", x, st["W"].astype(x.dtype))
            y = y * st["sc"].reshape(G, *([1] * len(lead)), m_hat)
        return _split_group(y, plan, lead, x.dtype)

    if kind == "block_diag":
        b = plan["b"]
        q = plan["d_in"] // b
        p_hat = plan["p"]
        xb = x.reshape(*lead, b, q)
        if storage == "float":
            y = jnp.einsum("...bq,gbqp->g...bp", xb, st["W"])
        else:
            y = jnp.einsum("...bq,gbqp->g...bp", xb, st["W"].astype(x.dtype))
            y = (y.astype(jnp.float32)
                 * st["sw"].reshape(G, *([1] * len(lead)), b, 1))
        y = y.reshape(G, *lead, b * p_hat)
        return _split_group(y, plan, lead, x.dtype)

    # -- blast ---------------------------------------------------------------
    b, p_hat = plan["b"], plan["p"]
    q = plan["d_in"] // b
    U, S, V = st["U"], st["S"], st["V"]
    if storage == "float":
        if use_pallas:
            from repro.kernels import ops as kops
            tpm = _TP_MESH[0]
            if tpm is not None:
                y = kops.blast_matmul_grouped_tp(x, U, S, V, mesh=tpm[0],
                                                 axis=tpm[1])
            else:
                y = kops.blast_matmul_grouped(x, U, S, V)
        else:
            xb = x.reshape(*lead, b, q)
            z = jnp.einsum("...jq,gjqr->g...jr", xb, V)
            w = jnp.einsum("g...jr,gijr->g...ir", z, S)
            y = jnp.einsum("g...ir,gipr->g...ip", w, U)
            y = y.reshape(G, *lead, b * p_hat)
        return _split_group(y, plan, lead, x.dtype)

    su, ss, sv = st["su"], st["ss"], st["sv"]
    act = activations_mode()
    if use_pallas:
        from repro.kernels import ops as kops
        tpm = _TP_MESH[0]
        if storage == "int4":
            if tpm is not None:
                y = kops.blast_matmul_grouped_q4_tp(
                    x, U, S, V, su, ss, sv, act=act,
                    mesh=tpm[0], axis=tpm[1])
            else:
                y = kops.blast_matmul_grouped_q4(x, U, S, V, su, ss, sv,
                                                 act=act)
        else:
            if tpm is not None:
                y = kops.blast_matmul_grouped_q_tp(
                    x, U, S, V, su, ss, sv, act=act,
                    mesh=tpm[0], axis=tpm[1])
            else:
                y = kops.blast_matmul_grouped_q(x, U, S, V, su, ss, sv,
                                                act=act)
    else:
        # XLA mirror of the fused grouped quant kernels: integer codes enter
        # the contraction, per-block scales multiply each stage's output
        # (int4 operands stay packed until here; plane order is exact).
        if storage == "int4":
            U, S, V = (qt.unpack_int4_planes(a) for a in (U, S, V))
        one = (1,) * len(lead)
        if act == "int8":
            xq, sx = qt.quantize_act(x)
            z = jnp.einsum("...jq,gjqr->g...jr", xq.reshape(*lead, b, q), V,
                           preferred_element_type=jnp.int32)
            z = (z.astype(jnp.float32) * sx[..., None]
                 * sv.reshape(G, *one, b, 1))
        else:
            xb = x.reshape(*lead, b, q)
            z = jnp.einsum("...jq,gjqr->g...jr", xb, V.astype(x.dtype))
            z = z.astype(jnp.float32) * sv.reshape(G, *one, b, 1)
        s = S.astype(jnp.float32) * ss[..., None]
        w = jnp.einsum("g...jr,gijr->g...ir", z, s)
        y = jnp.einsum("g...ir,gipr->g...ip", w, U.astype(jnp.float32))
        y = y * su.reshape(G, *one, b, 1)
        y = y.reshape(G, *lead, b * p_hat)
    return _split_group(y, plan, lead, x.dtype)
