"""BLAST matrix: parameterization, multiplication (Alg. 1), special cases.

Conventions
-----------
A BLAST matrix represents ``A ∈ R^{m×n}`` partitioned into ``b×b`` blocks of
size ``p×q`` (``m = b·p``, ``n = b·q``).  Block ``(i, j)`` is

    A_ij = U_i · diag(s_ij) · V_jᵀ,

with shared left factors ``U ∈ R^{b×p×r}`` (one per block-*row*), shared right
factors ``V ∈ R^{b×q×r}`` (one per block-*column*) and per-block diagonal
coupling ``S ∈ R^{b×b×r}``.

Layers consume the matrix as ``y = x @ Aᵀ`` for ``x: (..., n)`` → ``(..., m)``
(``n = d_in``, ``m = d_out``), which matches the paper's ``y = A x`` on column
vectors.

Parameter count:  ``(m + n)·r + b²·r``        (paper §2: ``2nr + rb²`` square)
Mat-vec mults:    ``(m + n)·r + b²·r``        (paper §2: ``(2n + b²)r`` square)
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class BlastParams(NamedTuple):
    """Pytree of BLAST factors.

    U: (b, p, r)   left factors, shared across each block-row
    S: (b, b, r)   diagonal coupling vectors, S[i, j] couples U_i with V_j
    V: (b, q, r)   right factors, shared across each block-column
    """

    U: jax.Array
    S: jax.Array
    V: jax.Array

    @property
    def b(self) -> int:
        return self.U.shape[0]

    @property
    def r(self) -> int:
        return self.U.shape[-1]

    @property
    def out_features(self) -> int:
        return self.U.shape[0] * self.U.shape[1]

    @property
    def in_features(self) -> int:
        return self.V.shape[0] * self.V.shape[1]


def check_divisible(m: int, n: int, b: int) -> tuple[int, int]:
    if m % b or n % b:
        raise ValueError(f"block count b={b} must divide both m={m} and n={n}")
    return m // b, n // b


def num_params(m: int, n: int, b: int, r: int) -> int:
    """Exact BLAST parameter count (paper §2)."""
    return (m + n) * r + b * b * r


def matvec_flops(m: int, n: int, b: int, r: int) -> int:
    """Multiplications per input vector (paper §2: (2n+b²)r for square)."""
    return (m + n) * r + b * b * r


def rank_for_budget(m: int, n: int, b: int, budget_params: float,
                    align: int = 1) -> int:
    """Largest rank whose parameter count stays within ``budget_params``.

    ``align > 1`` rounds down to a multiple (TP-shardable / MXU-friendly
    ranks; the paper itself rounds — Table 9 uses r=1024 where the exact
    50% solution is 993)."""
    r = int(budget_params // (m + n + b * b))
    if align > 1 and r >= 2 * align:
        r = (r // align) * align
    return max(r, 1)


def rank_for_compression(m: int, n: int, b: int, keep_ratio: float,
                         align: int = 1) -> int:
    """Rank so that BLAST params ≈ ``keep_ratio`` · (m·n) dense params.

    E.g. Table 9 of the paper: m=n=4096, b=16 at 50% keep → r=1024.
    """
    return rank_for_budget(m, n, b, keep_ratio * m * n, align=align)


def init(
    key: jax.Array,
    m: int,
    n: int,
    b: int,
    r: int,
    dtype=jnp.float32,
    factor_std: float | None = None,
    s_max: float = 2.0,
) -> BlastParams:
    """Random init for training from scratch (paper App. C.2 defaults).

    Paper: U, V ~ N(0, sqrt(0.02)·I);  s ~ Unif(0, 2).
    If ``factor_std`` is None we instead use a variance-scaling rule so the
    composed matrix has dense-init-like scale: std(A) ≈ sqrt(1/n) requires
    std_u·std_s_rms·std_v·sqrt(r) ≈ sqrt(1/n).
    """
    p, q = check_divisible(m, n, b)
    ku, kv, ks = jax.random.split(key, 3)
    if factor_std is None:
        # E[s²] for Unif(0, s_max) is s_max²/3 → rms = s_max/sqrt(3).
        s_rms = s_max / math.sqrt(3.0)
        factor_std = (1.0 / (n * r)) ** 0.25 / math.sqrt(s_rms)
    U = (factor_std * jax.random.normal(ku, (b, p, r))).astype(dtype)
    V = (factor_std * jax.random.normal(kv, (b, q, r))).astype(dtype)
    S = jax.random.uniform(ks, (b, b, r), minval=0.0, maxval=s_max).astype(dtype)
    return BlastParams(U=U, S=S, V=V)


def init_paper(key: jax.Array, m: int, n: int, b: int, r: int, dtype=jnp.float32) -> BlastParams:
    """Exact paper App. C.2 initialization (std = sqrt(0.02), s ~ U(0,2))."""
    return init(key, m, n, b, r, dtype=dtype, factor_std=math.sqrt(0.02), s_max=2.0)


def matmul(x: jax.Array, params: BlastParams, *, precision=None) -> jax.Array:
    """Alg. 1: y = x @ Aᵀ for x: (..., n) → (..., m).

    Three stages (all dense, accelerator-friendly):
      z_j = V_jᵀ x_j            -- batched GEMM over input blocks
      w_i = Σ_j s_ij ⊙ z_j      -- block-coupled scaled reduction
      y_i = U_i w_i             -- batched GEMM over output blocks
    """
    U, S, V = params.U, params.S, params.V
    b, q, r = V.shape
    p = U.shape[1]
    lead = x.shape[:-1]
    xb = x.reshape(*lead, b, q)
    z = jnp.einsum("...jq,jqr->...jr", xb, V, precision=precision)
    w = jnp.einsum("...jr,ijr->...ir", z, S, precision=precision)
    y = jnp.einsum("...ir,ipr->...ip", w, U, precision=precision)
    return y.reshape(*lead, b * p)


def to_dense(params: BlastParams, dtype=None) -> jax.Array:
    """Materialize the full A ∈ R^{m×n} (tests / compression residuals)."""
    U, S, V = params.U, params.S, params.V
    blocks = jnp.einsum("ipr,ijr,jqr->ijpq", U, S, V)
    b, _, p, q = blocks.shape
    dense = blocks.transpose(0, 2, 1, 3).reshape(b * p, b * q)
    return dense if dtype is None else dense.astype(dtype)


# ---------------------------------------------------------------------------
# Special cases (paper §2 and App. A.1): exact embeddings into BLAST.
# ---------------------------------------------------------------------------


def from_low_rank(w_down: jax.Array, w_up: jax.Array, b: int) -> BlastParams:
    """Low-rank ``A = w_upᵀ @ w_downᵀ`` as BLAST with all-ones coupling.

    w_down: (n, t) and w_up: (t, m) as used by ``y = (x @ w_down) @ w_up``.
    """
    n, t = w_down.shape
    m = w_up.shape[1]
    p, q = check_divisible(m, n, b)
    U = w_up.T.reshape(b, p, t)
    V = w_down.reshape(b, q, t)
    S = jnp.ones((b, b, t), dtype=w_down.dtype)
    return BlastParams(U=U, S=S, V=V)


def from_block_diagonal(w_bd: jax.Array) -> BlastParams:
    """Block-diagonal ``y_i = x_i @ w_i`` (w_bd: (b, q, p)) as BLAST (r = q)."""
    b, q, p = w_bd.shape
    U = jnp.swapaxes(w_bd, 1, 2)  # (b, p, q): U_i = w_iᵀ
    V = jnp.broadcast_to(jnp.eye(q, dtype=w_bd.dtype), (b, q, q))
    S = jnp.zeros((b, b, q), dtype=w_bd.dtype)
    S = S.at[jnp.arange(b), jnp.arange(b)].set(1.0)
    return BlastParams(U=U, S=S, V=V)


def from_monarch(L: jax.Array, R: jax.Array) -> BlastParams:
    """Monarch (L: (b, q, k), R: (k, b, c) with c == b) as BLAST with r = k.

    Our Monarch convention (see structures.py): out-block i = c-index,
    M_ij[k0, q0] = L[j, q0, k0] · R[k0, j, i].  Exact BLAST embedding:
    U_i = I_k,  V_j = L[j],  s_ij[ρ] = R[ρ, j, i].
    """
    b, q, k = L.shape
    k2, b2, c = R.shape
    if k2 != k or b2 != b or c != b:
        raise ValueError("from_monarch requires R: (k, b, b) matching L: (b, q, k)")
    U = jnp.broadcast_to(jnp.eye(k, dtype=L.dtype), (b, k, k))
    V = L
    S = jnp.einsum("rjc->cjr", R)  # s_ij[ρ] = R[ρ, j, i]
    return BlastParams(U=U, S=S, V=V)


def from_dense_svd(w: jax.Array, b: int, r: int) -> BlastParams:
    """Quick spectral init: global truncated SVD of A = wᵀ embedded in BLAST.

    Used as a warm start for Algorithm 2 (optional) and as a sanity baseline.
    w: (n, m) layer weight with y = x @ w.
    """
    n, m = w.shape
    a = w.T.astype(jnp.float32)  # (m, n)
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    t = min(r, s.shape[0])
    w_up = (u[:, :t] * s[:t]).T  # (t, m)
    w_down = vt[:t].T  # (n, t)
    params = from_low_rank(w_down, w_up, b)
    if t < r:  # zero-pad rank to requested r
        pad = r - t
        U = jnp.pad(params.U, ((0, 0), (0, 0), (0, pad)))
        V = jnp.pad(params.V, ((0, 0), (0, 0), (0, pad)))
        S = jnp.pad(params.S, ((0, 0), (0, 0), (0, pad)))
        params = BlastParams(U=U, S=S, V=V)
    return BlastParams(*(x.astype(w.dtype) for x in params))
