"""Core of the paper's contribution: the BLAST structured matrix.

- ``blast``       parameterization, Alg. 1 matmul, special-case embeddings
- ``structures``  unified structured-linear interface (+ paper baselines)
- ``factorize``   Alg. 2 compression (GD / preconditioned GD)
- ``compress``    whole-model compression driver
"""

from repro.core import blast, factorize, structures  # noqa: F401
from repro.core.blast import BlastParams  # noqa: F401
from repro.core.structures import LinearSpec, StructureConfig, make_linear  # noqa: F401
