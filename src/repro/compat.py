"""JAX API compatibility shims.

The launch/test code targets the modern mesh API (``jax.sharding.AxisType``,
``AbstractMesh(axis_sizes, axis_names)``, ``jax.make_mesh(..., axis_types=)``)
while the container may pin an older jax (0.4.x) that predates it.  The shims
below backfill the new surface on old jax so the same code runs on both; on a
new-enough jax every installer is a no-op.

``install()`` runs once at ``import repro`` (see ``repro/__init__.py``), so
anything that imports the package — tests via ``tests/conftest.py``, the
launchers, subprocess dry-runs — gets a consistent API.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.sharding

_installed = False


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        """Backfill of jax.sharding.AxisType (auto is old-jax's only mode)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    sig = inspect.signature(jax.make_mesh)
    if "axis_types" in sig.parameters:
        return
    orig = jax.make_mesh

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *args, axis_types=None, **kwargs):
        # old jax has no axis_types concept — every axis behaves as Auto,
        # which is the only value our callers pass.
        return orig(axis_shapes, axis_names, *args, **kwargs)

    jax.make_mesh = make_mesh


def _install_abstract_mesh() -> None:
    orig = jax.sharding.AbstractMesh
    params = inspect.signature(orig.__init__).parameters
    if "shape_tuple" not in params:
        return  # new-style signature already

    @functools.wraps(orig, updated=())
    def abstract_mesh(axis_sizes, axis_names=None, *, axis_types=None):
        if axis_names is None:
            return orig(axis_sizes)  # old-style shape_tuple passthrough
        return orig(tuple(zip(axis_names, axis_sizes)))

    jax.sharding.AbstractMesh = abstract_mesh


def _install_shard_map() -> None:
    """Backfill ``jax.shard_map`` (new-jax top-level surface, ``check_vma``
    kwarg) on top of ``jax.experimental.shard_map`` (old jax, ``check_rep``).
    Callers (models/moe.py, kernels/ops.py) always go through ``jax.shard_map``
    with ``check_vma=`` — on old jax that maps onto ``check_rep=``."""
    if hasattr(jax, "shard_map"):
        if "check_vma" in inspect.signature(jax.shard_map).parameters:
            return
        orig = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as orig

    @functools.wraps(orig)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
        if check_vma is not None and "check_rep" not in kwargs:
            kwargs["check_rep"] = bool(check_vma)
        return orig(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    **kwargs)

    jax.shard_map = shard_map


def install() -> None:
    global _installed
    if _installed:
        return
    _install_axis_type()
    _install_make_mesh()
    _install_abstract_mesh()
    _install_shard_map()
    _installed = True
