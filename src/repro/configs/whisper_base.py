"""whisper-base [audio] — enc-dec transformer backbone [arXiv:2212.04356].

6 encoder + 6 decoder layers, d_model=512, 8 heads (head_dim=64), d_ff=2048,
vocab=51865, GELU FFN, LayerNorm, sinusoidal positions.  The conv/mel
frontend is a STUB: input_specs provides (B, 1500, 512) frame embeddings.
long_500k skipped (full attention); decode shapes exercise the decoder."""

from repro.configs.base import ArchConfig, EncoderCfg
from repro.core.structures import StructureConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    vocab=51_865,
    d_model=512,
    n_layers=6,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    ffn_kind="gelu",
    norm="layernorm",
    pos_embed="sinusoidal",
    tie_embeddings=True,
    pattern=("attn",),
    encoder=EncoderCfg(n_layers=6, n_frames=1500),
    embeds_input=True,
    scan_layers=False,         # 6+6 layers: unrolled is cheaper than scan
    structure=StructureConfig(kind="blast", b=16, keep_ratio=0.5),
)
