"""qwen1.5-32b [dense] — QKV bias [hf:Qwen/Qwen1.5-32B].

64L, d_model=5120, 40 heads (kv=40, head_dim=128), d_ff=27392, vocab=152064."""

from repro.configs.base import ArchConfig
from repro.core.structures import StructureConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    vocab=152_064,
    d_model=5120,
    n_layers=64,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    ffn_kind="swiglu",
    qkv_bias=True,
    pattern=("attn",),
    structure=StructureConfig(kind="blast", b=16, keep_ratio=0.5),
)
