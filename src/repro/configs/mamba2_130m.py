"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L, d_model=768, attention-free (d_ff=0 — the SSD mixer is the whole
block), vocab=50280, ssm_state=128, head_dim=64, expand=2 (d_inner=1536,
24 SSD heads).  Sub-quadratic → runs long_500k.

BLAST applies to in_proj/out_proj; the SSD recurrence itself has no weight
matrix (DESIGN.md §Arch-applicability)."""

from repro.configs.base import ArchConfig, SSDCfg
from repro.core.structures import StructureConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    vocab=50_280,
    d_model=768,
    n_layers=24,
    n_heads=24,
    n_kv_heads=24,
    d_ff=0,
    ffn_kind="none",
    tie_embeddings=True,
    pos_embed="none",
    pattern=("ssd",),
    ssd=SSDCfg(d_state=128, head_dim=64, expand=2, chunk=128, conv_width=4),
    sub_quadratic=True,
    structure=StructureConfig(kind="blast", b=16, keep_ratio=0.5),
)
