"""granite-3-2b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base].

40L, d_model=2048, 32 heads (GQA kv=8, head_dim=64), d_ff=8192, vocab=49155,
tied embeddings."""

from repro.configs.base import ArchConfig
from repro.core.structures import StructureConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    vocab=49_155,
    d_model=2048,
    n_layers=40,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    ffn_kind="swiglu",
    tie_embeddings=True,
    pattern=("attn",),
    structure=StructureConfig(kind="blast", b=16, keep_ratio=0.5),
)
