"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427 (Griffin); hf google/recurrentgemma-2b].

26L, d_model=2560, 10 heads (GQA kv=1, head_dim=256), d_ff=7680,
vocab=256000, sliding window 2048.  26 = 8 full (rec, rec, attn) cycles + a
2-layer recurrent tail (handled by the scan/tail decomposition).
Sub-quadratic (O(1) recurrent state + O(window) ring KV) → runs long_500k.
"""

from repro.configs.base import ArchConfig, RGLRUCfg
from repro.core.structures import StructureConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    vocab=256_000,
    d_model=2560,
    n_layers=26,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    ffn_kind="gelu",
    norm="rmsnorm",
    pos_embed="rope",
    tie_embeddings=True,
    embed_scale=True,
    logit_softcap=30.0,
    pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    rglru=RGLRUCfg(lru_width=2560, conv_width=4, c=8.0),
    sub_quadratic=True,
    structure=StructureConfig(kind="blast", b=16, keep_ratio=0.5),
)
