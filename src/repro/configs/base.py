"""Architecture / run configuration schema.

Every assigned architecture is expressed as an ``ArchConfig``; the BLAST
structure (or any baseline structure) is selected orthogonally via
``StructureConfig`` so each arch runs as dense or compressed.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.structures import StructureConfig
from repro.quant import QuantConfig


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    d_shared: int = 0
    first_dense_layers: int = 0
    dense_d_ff: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int
    kv_lora_rank: int
    rope_head_dim: int
    nope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSDCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class RGLRUCfg:
    lru_width: int = 0      # 0 → d_model
    conv_width: int = 4
    c: float = 8.0


@dataclasses.dataclass(frozen=True)
class EncoderCfg:
    """Whisper-style encoder; the conv/mel frontend is a stub — input_specs
    provides precomputed frame embeddings (B, n_frames, d_model)."""

    n_layers: int
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    head_dim: int = 0                 # 0 → d_model // n_heads
    ffn_kind: str = "swiglu"          # swiglu | gelu | none
    qkv_bias: bool = False
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    rope_theta: float = 10000.0
    pos_embed: str = "rope"           # rope | learned | sinusoidal | none
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    embed_scale: bool = False         # gemma-style sqrt(d_model) embed scaling

    # per-layer mixer pattern, cycled over n_layers:
    #   'attn' | 'local_attn' | 'rglru' | 'ssd' | 'mla'
    pattern: Sequence[str] = ("attn",)
    window: int = 0                   # sliding-window size for 'local_attn'

    moe: MoECfg | None = None
    mla: MLACfg | None = None
    ssd: SSDCfg | None = None
    rglru: RGLRUCfg | None = None
    encoder: EncoderCfg | None = None
    mtp: bool = False                 # DeepSeek-V3 multi-token prediction head

    embeds_input: bool = False        # llava/whisper-enc: inputs are embeddings
    sub_quadratic: bool = False       # supports long_500k decode

    # structure of the linear layers (the paper's technique).  ``structure``
    # covers attention/mixer projections; ``structure_ffn`` (if set) overrides
    # for FFN / MoE-expert linears — the paper uses different ranks per role
    # (Table 9: r=1024 attn, r=1488 MLP for Llama-7B at 50%).
    structure: StructureConfig = dataclasses.field(default_factory=StructureConfig)
    structure_ffn: StructureConfig | None = None
    max_seq: int = 8192               # learned-pos table size (pos_embed=learned)

    # execution
    # legacy flag, now a full alias for quant.cache="int8" (quantizes every
    # family's cache — MLA latent and SSD/RG-LRU state included, not just
    # attention KV as before PR 4)
    kv_quant: bool = False
    # serving-time storage formats (weights / caches); see repro/quant.
    # ``quant.weights`` drives Engine quantize-at-load and LM.quantize_params;
    # ``quant.cache`` switches every family's KV/latent/state cache to int8.
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    scan_layers: bool = True
    remat: bool = True
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"  # AdamW m/v dtype (bf16 for huge archs)
    q_chunk: int = 512                # chunked-attention tile sizes (XLA path)
    kv_chunk: int = 1024

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def cache_quant(self) -> bool:
        """int8 caches requested (new ``quant.cache`` knob or legacy flag)."""
        return self.kv_quant or self.quant.cache != "none"

    @property
    def ffn_structure(self) -> StructureConfig:
        return self.structure_ffn or self.structure

    def layer_kinds(self) -> list[str]:
        pat = list(self.pattern)
        return [pat[i % len(pat)] for i in range(self.n_layers)]

    def with_structure(self, structure: StructureConfig) -> "ArchConfig":
        return dataclasses.replace(self, structure=structure)

    def reduced(self, **overrides) -> "ArchConfig":
        """Small same-family variant for CPU smoke tests."""
        small: dict = dict(
            vocab=min(self.vocab, 512),
            d_model=min(self.d_model, 64),
            n_layers=min(self.n_layers, len(self.pattern) * 2),
            d_ff=min(self.d_ff, 128) if self.d_ff else 0,
            param_dtype="float32",
            compute_dtype="float32",
            scan_layers=self.scan_layers,
            remat=False,
            q_chunk=32,
            kv_chunk=32,
        )
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        small.update(n_heads=n_heads, n_kv_heads=n_kv, head_dim=16)
        if self.window:
            small["window"] = 16
        if self.moe:
            small["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2), d_expert=32,
                d_shared=32 if self.moe.n_shared else 0,
                dense_d_ff=64 if self.moe.first_dense_layers else 0,
                first_dense_layers=min(self.moe.first_dense_layers, 1))
            small["n_layers"] = max(small["n_layers"],
                                    (self.moe.first_dense_layers and 1) + 2)
        if self.mla:
            small["mla"] = MLACfg(q_lora_rank=32, kv_lora_rank=16,
                                  rope_head_dim=8, nope_head_dim=16, v_head_dim=16)
            small["head_dim"] = 0
        if self.ssd:
            small["ssd"] = dataclasses.replace(self.ssd, d_state=16, head_dim=8, chunk=8)
        if self.rglru:
            small["rglru"] = dataclasses.replace(self.rglru, lru_width=0)
        if self.encoder:
            small["encoder"] = dataclasses.replace(self.encoder, n_layers=2, n_frames=24)
        def shrink(st):
            if st is not None and st.kind in ("blast", "monarch", "block_diag"):
                return dataclasses.replace(st, b=min(st.b, 4), rank=None)
            return st
        small["structure"] = shrink(self.structure)
        small["structure_ffn"] = shrink(self.structure_ffn)
        small["max_seq"] = 256
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Assigned input-shape grid (the 4 shapes every LM arch is paired with).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """Whether a (arch × shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (skip: full-attn arch)"
    return True, ""
