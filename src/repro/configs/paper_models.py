"""The paper's own experimental models (§4): GPT-2 (Fig. 5), ViT-Base
(Tables 1, Fig. 6) and Llama-7B (Tables 3/4/9).

Llama-7B uses the paper's exact Table-9 BLAST parameters: b=16, r=1024 for
attention and r=1488 for MLP at the 50% compression ratio — reproduced here
via the per-role structure override (structure vs structure_ffn)."""

from repro.configs.base import ArchConfig
from repro.core.structures import StructureConfig

GPT2_BLAST = ArchConfig(
    name="gpt2-blast",
    family="dense",
    vocab=50_257,
    d_model=768,
    n_layers=12,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    ffn_kind="gelu",
    norm="layernorm",
    pos_embed="learned",
    max_seq=4096,
    tie_embeddings=True,
    pattern=("attn",),
    # paper §4.1: GPT-2 trained from scratch with BLAST_6
    structure=StructureConfig(kind="blast", b=6, keep_ratio=0.5),
)

# ViT-Base shape (the from-scratch §4.1 / compression §4.2 target); the
# actual ViT model (patch embed + encoder + classifier) is models/vit.py.
VIT_BLAST = ArchConfig(
    name="vit-base-blast",
    family="vision",
    vocab=1000,                # = number of classes
    d_model=768,
    n_layers=12,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    ffn_kind="gelu",
    norm="layernorm",
    pos_embed="learned",
    pattern=("attn",),
    embeds_input=True,
    # paper: BLAST_3 for ViT from scratch
    structure=StructureConfig(kind="blast", b=3, keep_ratio=0.3),
)

LLAMA7B_BLAST = ArchConfig(
    name="llama7b-blast",
    family="dense",
    vocab=32_000,
    d_model=4096,
    n_layers=32,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    ffn_kind="swiglu",
    pattern=("attn",),
    # paper Table 9: 50% CR → r=1024 (attn), r=1488 (MLP), b=16
    structure=StructureConfig(kind="blast", b=16, rank=1024),
    structure_ffn=StructureConfig(kind="blast", b=16, rank=1488),
)
