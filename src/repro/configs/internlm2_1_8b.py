"""internlm2-1.8b [dense] — GQA [arXiv:2403.17297].

24L, d_model=2048, 16 heads (GQA kv=8, head_dim=128), d_ff=8192, vocab=92544."""

from repro.configs.base import ArchConfig
from repro.core.structures import StructureConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    vocab=92_544,
    d_model=2048,
    n_layers=24,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    ffn_kind="swiglu",
    pattern=("attn",),
    structure=StructureConfig(kind="blast", b=16, keep_ratio=0.5),
)
