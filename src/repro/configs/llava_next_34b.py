"""llava-next-34b [vlm] — anyres tiling [hf:llava-hf/llava-v1.6-34b-hf].

Transformer BACKBONE only (Yi-34B-family decoder): 60L, d_model=7168,
56 heads (GQA kv=8, head_dim=128), d_ff=20480, vocab=64000.  The anyres
vision frontend is a STUB — input_specs provides precomputed patch
embeddings (B, T, d_model) for train/prefill; decode generates text tokens
with the regular embedding table."""

from repro.configs.base import ArchConfig
from repro.core.structures import StructureConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    vocab=64_000,
    d_model=7168,
    n_layers=60,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    ffn_kind="swiglu",
    pattern=("attn",),
    embeds_input=True,
    structure=StructureConfig(kind="blast", b=16, keep_ratio=0.5),
)
