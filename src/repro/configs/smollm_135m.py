"""smollm-135m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M].

30L, d_model=576, 9 heads (GQA kv=3, head_dim=64), d_ff=1536, vocab=49152,
tied embeddings."""

from repro.configs.base import ArchConfig
from repro.core.structures import StructureConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    vocab=49_152,
    d_model=576,
    n_layers=30,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    ffn_kind="swiglu",
    tie_embeddings=True,
    pattern=("attn",),
    structure=StructureConfig(kind="blast", b=16, keep_ratio=0.5),
)
