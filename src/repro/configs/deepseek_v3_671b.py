"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed experts (top-8) + MTP
[arXiv:2412.19437].

61L, d_model=7168, 128 heads, per-expert d_ff=2048, vocab=129280.
First 3 layers use a dense 18432-wide FFN (the paper's warmup-dense layers);
the remaining 58 are MoE and run under the layer scan.  MLA dims are the
published ones (q_lora=1536, kv_lora=512, nope=128, rope=64, v=128); decode
uses the latent KV cache with absorbed up-projections."""

from repro.configs.base import ArchConfig, MLACfg, MoECfg
from repro.core.structures import StructureConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    vocab=129_280,
    d_model=7168,
    n_layers=61,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,                # per routed expert
    ffn_kind="swiglu",
    pattern=("mla",),
    mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
               nope_head_dim=128, v_head_dim=128),
    moe=MoECfg(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
               d_shared=2048, first_dense_layers=3, dense_d_ff=18432,
               capacity_factor=1.25),
    mtp=True,
    optimizer_dtype="bfloat16",   # 671B fp32 m/v would not fit 512 chips
    structure=StructureConfig(kind="blast", b=16, keep_ratio=0.5),
)
