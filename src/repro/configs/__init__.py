"""Architecture registry: the 10 assigned archs + the paper's own models.

Every config is importable and selectable via ``--arch <id>``; the BLAST
structure (keep=0.5, b=16 — the paper's Llama-7B headline setting) is the
default for assigned archs; ``variant(cfg, 'dense'|'blast50'|...)`` switches
the structure without touching the architecture.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, SHAPES, ShapeCfg, shape_applicable  # noqa: F401
from repro.core.structures import StructureConfig

from repro.configs.recurrentgemma_2b import CONFIG as recurrentgemma_2b
from repro.configs.granite_moe_1b import CONFIG as granite_moe_1b
from repro.configs.deepseek_v3_671b import CONFIG as deepseek_v3_671b
from repro.configs.smollm_135m import CONFIG as smollm_135m
from repro.configs.internlm2_1_8b import CONFIG as internlm2_1_8b
from repro.configs.granite_3_2b import CONFIG as granite_3_2b
from repro.configs.qwen1_5_32b import CONFIG as qwen1_5_32b
from repro.configs.mamba2_130m import CONFIG as mamba2_130m
from repro.configs.whisper_base import CONFIG as whisper_base
from repro.configs.llava_next_34b import CONFIG as llava_next_34b
from repro.configs.paper_models import GPT2_BLAST, VIT_BLAST, LLAMA7B_BLAST

ARCHS: dict[str, ArchConfig] = {
    "recurrentgemma-2b": recurrentgemma_2b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "smollm-135m": smollm_135m,
    "internlm2-1.8b": internlm2_1_8b,
    "granite-3-2b": granite_3_2b,
    "qwen1.5-32b": qwen1_5_32b,
    "mamba2-130m": mamba2_130m,
    "whisper-base": whisper_base,
    "llava-next-34b": llava_next_34b,
    # paper's own models
    "gpt2-blast": GPT2_BLAST,
    "vit-base-blast": VIT_BLAST,
    "llama7b-blast": LLAMA7B_BLAST,
}

ASSIGNED = [k for k in ARCHS if not k.endswith("-blast")]

VARIANTS = ("blast50", "blast80", "dense", "low_rank50", "monarch50",
            "block_diag", "pixelfly50")


def variant(cfg: ArchConfig, name: str) -> ArchConfig:
    """Swap the linear-layer structure, keeping the architecture fixed."""
    b = cfg.structure.b if cfg.structure.kind in ("blast", "monarch") else 16
    table = {
        "dense": StructureConfig(kind="dense"),
        "blast50": StructureConfig(kind="blast", b=b, keep_ratio=0.5),
        "blast80": StructureConfig(kind="blast", b=b, keep_ratio=0.8),
        "low_rank50": StructureConfig(kind="low_rank", keep_ratio=0.5),
        "monarch50": StructureConfig(kind="monarch", b=b, keep_ratio=0.5),
        "block_diag": StructureConfig(kind="block_diag", b=b, keep_ratio=0.5),
        "pixelfly50": StructureConfig(kind="pixelfly", b=b, keep_ratio=0.5),
    }
    st = table[name]
    return dataclasses.replace(cfg, structure=st, structure_ffn=None)


def get(name: str, structure: str | None = None) -> ArchConfig:
    cfg = ARCHS[name]
    return variant(cfg, structure) if structure else cfg
