"""granite-moe-1b-a400m [moe] — 32 experts, top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].

24L, d_model=1024, 16 heads (GQA kv=8, head_dim=64), per-expert d_ff=512,
vocab=49155.  Every layer is MoE; router kept dense (accuracy-critical,
tiny — the paper analogously keeps the LM head dense)."""

from repro.configs.base import ArchConfig, MoECfg
from repro.core.structures import StructureConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    vocab=49_155,
    d_model=1024,
    n_layers=24,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                 # per-expert width
    ffn_kind="swiglu",
    tie_embeddings=True,
    pattern=("attn",),
    moe=MoECfg(n_experts=32, top_k=8, d_expert=512, capacity_factor=1.25),
    structure=StructureConfig(kind="blast", b=16, keep_ratio=0.5),
)
