"""Model-level numeric ops: chunked (flash-style) attention for the XLA/GSPMD
path, RoPE, norms, activations.

The chunked attention is the pure-XLA analogue of kernels/flash_attention.py:
q is processed in *statically unrolled* chunks so each chunk only contracts
against the causally-reachable (or window-reachable) slice of K/V — no
full T×S score matrix is ever materialized, and causal/window skipping is
reflected in the compiled FLOPs (what the roofline reads).  On real TPUs the
Pallas kernel replaces this inside shard_map; both share ref.py semantics.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding.  x: (B, T, H, D) even D; positions: (T,) or (B, T)."""
    dtype = x.dtype
    d_half = x.shape[-1] // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(d_half, dtype=jnp.float32) / d_half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (T, d/2)
        ang = ang[None, :, None, :]
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, T, d/2)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos * jnp.exp(-math.log(10000.0) * dim / (d // 2))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _attn_block(q, k, v, mask, scale):
    """One (q-chunk × kv-slice) attention block in fp32."""
    s = jnp.einsum("bhgtd,bhsd->bhgts", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.maximum(m, -1e30)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgts,bhse->bhgte", p, v, preferred_element_type=jnp.float32)
    return o, m[..., 0], l[..., 0]


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """q: (B, Hq, T, Dk); k: (B, Hkv, S, Dk); v: (B, Hkv, S, Dv) → (B, Hq, T, Dv).

    Statically-unrolled q chunks; each contracts only its reachable KV slice
    (causal upper bound / sliding-window lower bound, both static).
    """
    B, Hq, T, Dk = q.shape
    _, Hkv, S, Dv = k.shape[0], k.shape[1], k.shape[2], v.shape[3]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dk)
    qg = q.reshape(B, Hkv, G, T, Dk).astype(jnp.float32)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)

    # Cap the static unroll at 8 chunks: keeps the HLO (and compile time)
    # bounded for 32k+ prefill while still skipping ~44% of causal work.
    q_chunk = max(q_chunk, -(-T // 8))
    q_chunk = min(q_chunk, T)
    n_chunks = (T + q_chunk - 1) // q_chunk
    outs = []
    for ci in range(n_chunks):
        t0 = ci * q_chunk
        t1 = min(T, t0 + q_chunk)
        tc = t1 - t0
        qc = qg[:, :, :, t0:t1]
        # static reachable KV range for this q chunk
        hi = min(S, q_offset + t1) if causal else S
        lo = 0
        if window is not None:
            lo = max(0, q_offset + t0 - window + 1)
        kc = kf[:, :, lo:hi]
        vc = vf[:, :, lo:hi]
        q_pos = q_offset + jnp.arange(t0, t1)
        k_pos = jnp.arange(lo, hi)
        mask = jnp.ones((tc, hi - lo), dtype=bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        o, m, l = _attn_block(qc, kc, vc, mask[None, None, None], scale)
        safe = jnp.where(l > 0, l, 1.0)
        outs.append(o / safe[..., None])
    out = jnp.concatenate(outs, axis=3) if len(outs) > 1 else outs[0]
    return out.reshape(B, Hq, T, Dv).astype(q.dtype)


def cache_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    k_pos: jax.Array,
    q_pos: jax.Array,
    *,
    window: int | None = None,
) -> jax.Array:
    """Multi-token decode/prefill attention over a (possibly ring) cache.

    q: (B, Hq, T, Dk); k_cache/v_cache: (B, S_alloc, Hkv, D*);
    k_pos: (B, S_alloc) absolute position of each slot (-1 = empty);
    q_pos: (B,) or (B, T) absolute position of each query row — per-slot
    offsets for continuous batching; T=1 is classic single-token decode,
    T=C a prefill chunk (intra-chunk causality falls out of the position
    comparison).
    """
    B, Hq, T, Dk = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dk)
    q_pos = jnp.asarray(q_pos, jnp.int32)
    if q_pos.ndim == 1:
        q_pos = q_pos[:, None]
    q_pos = jnp.broadcast_to(q_pos, (B, T))
    qf = q.reshape(B, Hkv, G, T, Dk).astype(jnp.float32)
    s = jnp.einsum("bhgtd,bshd->bhgts", qf, k_cache.astype(jnp.float32)) * scale
    valid = (k_pos[:, None, :] >= 0) & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        valid &= k_pos[:, None, :] > q_pos[:, :, None] - window
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-empty caches
    o = jnp.einsum("bhgts,bshe->bhgte", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, T, o.shape[-1]).astype(q.dtype)


def causal_conv_chunk(cache_conv: jax.Array, x: jax.Array, w: jax.Array,
                      b: jax.Array, n_tokens: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over [cached history ‖ chunk], ragged rows.

    cache_conv: (B, K-1, ch) — each row's last K-1 pre-conv inputs;
    x: (B, C, ch) — the chunk's pre-conv inputs, live prefix per row given
    by n_tokens (dead tail columns produce garbage outputs their caller
    discards, and never enter the returned cache); w: (K, ch); b: (ch,).
    Returns (y (B, C, ch), new_cache_conv (B, K-1, ch)) — equal to C
    sequential single-token conv steps, computed position-parallel (live
    columns only depend on earlier live/cached inputs since dead columns
    form a contiguous tail).  Shared by the SSD and RG-LRU prefills.
    """
    K, C = w.shape[0], x.shape[1]
    hist = jnp.concatenate([cache_conv, x], axis=1)    # (B, K-1+C, ch)
    y = b
    for k in range(K):
        y = y + hist[:, k:k + C] * w[k]
    # new cache: each row's last K-1 live inputs (hist index i holds the
    # input at position i-(K-1) relative to the chunk start)
    idx = n_tokens[:, None] + jnp.arange(K - 1)[None, :]
    tail = jnp.take_along_axis(hist, idx[:, :, None], axis=1)
    return y, tail.astype(cache_conv.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap else x


def cross_entropy(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Mean token CE + accuracy.  logits (..., V) fp32-stable."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, acc
