"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, n_frames, d_model).  The transformer
backbone is real: bidirectional encoder, causal decoder with per-layer
cross-attention, tied LM head.  Linear layers are structured (BLAST-able)
exactly like the decoder-only models.

Decode: ``encode()`` runs once and precomputes every decoder layer's
cross-attention K/V; ``decode_step`` then attends to the fixed memory cache
while growing the self-attention cache.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ops
from repro.models.transformer import (block_apply, block_axes,
                                      block_cache_axes, block_cache_init,
                                      block_decode, block_init,
                                      block_quantize, make_block, Output)
from repro.parallel import Parallel, NO_PARALLEL
from repro.quant import QuantConfig

Params = dict[str, Any]


class EncDec:
    """Whisper-family enc-dec LM."""

    def __init__(self, cfg: ArchConfig, parallel: Parallel = NO_PARALLEL):
        assert cfg.encoder is not None
        self.cfg = cfg
        self.parallel = parallel
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.enc_specs = [make_block(cfg, "attn", causal=False)
                          for _ in range(cfg.encoder.n_layers)]
        self.dec_specs = [make_block(cfg, "attn", cross=True)
                          for _ in range(cfg.n_layers)]

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        params: Params = {
            "embed": (0.02 * jax.random.normal(
                ks[0], (cfg.vocab, cfg.d_model))).astype(self.dtype),
            "enc_norm": L.norm_init(cfg.d_model, cfg.norm, self.dtype),
            "final_norm": L.norm_init(cfg.d_model, cfg.norm, self.dtype),
        }
        for i, spec in enumerate(self.enc_specs):
            params[f"enc_{i}"] = block_init(
                spec, jax.random.fold_in(ks[1], i), self.dtype, cfg.d_model)
        for i, spec in enumerate(self.dec_specs):
            params[f"dec_{i}"] = block_init(
                spec, jax.random.fold_in(ks[2], i), self.dtype, cfg.d_model)
        return params

    def axes(self) -> dict:
        a: dict = {"embed": ("vocab", "embed"),
                   "enc_norm": L.norm_axes(self.cfg.norm),
                   "final_norm": L.norm_axes(self.cfg.norm)}
        for i, spec in enumerate(self.enc_specs):
            a[f"enc_{i}"] = block_axes(spec)
        for i, spec in enumerate(self.dec_specs):
            a[f"dec_{i}"] = block_axes(spec)
        return a

    def quantize_params(self, params: Params, quant: QuantConfig) -> Params:
        """Quantize-at-load for the enc-dec: every block's structured
        linears, plus the tied embedding table per-row (both the gather and
        the tied head fuse its scales)."""
        bits = quant.weight_bits
        if bits is None:
            return params
        qp = dict(params)
        from repro import quant as qt
        qp["embed"] = qt.quantize(params["embed"], bits=bits, block_axes=(1,))
        for i, spec in enumerate(self.enc_specs):
            qp[f"enc_{i}"] = block_quantize(spec, params[f"enc_{i}"], bits)
        for i, spec in enumerate(self.dec_specs):
            qp[f"dec_{i}"] = block_quantize(spec, params[f"dec_{i}"], bits)
        return qp

    # -- encoder ---------------------------------------------------------------

    def encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """frames: (B, F, d_model) stub-frontend embeddings → memory."""
        cfg, parallel = self.cfg, self.parallel
        F = frames.shape[1]
        x = frames.astype(self.dtype) + ops.sinusoidal_positions(
            F, cfg.d_model).astype(self.dtype)[None]
        x = parallel.shard_batch(x)
        positions = jnp.arange(F)
        for i, spec in enumerate(self.enc_specs):
            x, _ = block_apply(spec, params[f"enc_{i}"], x, positions, parallel)
        return L.norm_apply(params["enc_norm"], x, cfg.norm)

    # -- decoder ---------------------------------------------------------------

    def apply(self, params: Params, tokens: jax.Array,
              frames: jax.Array, *, last_only: bool = False) -> Output:
        """Teacher-forced training forward.  tokens: (B, T); frames: (B, F, d)."""
        cfg, parallel = self.cfg, self.parallel
        memory = self.encode(params, frames)
        T = tokens.shape[1]
        x = L.embed_lookup(params["embed"], tokens, self.dtype) \
            + ops.sinusoidal_positions(T, cfg.d_model).astype(self.dtype)[None]
        x = parallel.shard_batch(x)
        positions = jnp.arange(T)
        for i, spec in enumerate(self.dec_specs):
            x, _ = block_apply(spec, params[f"dec_{i}"], x, positions, parallel,
                               memory=memory)
        if last_only:
            x = x[:, -1:]
        x = L.norm_apply(params["final_norm"], x, cfg.norm)
        logits = L.tied_logits(params["embed"], x)  # tied head (whisper)
        logits = parallel.constraint(
            logits, parallel.batch_spec(None, parallel.model_axis))
        return Output(logits=logits, aux=jnp.zeros((), jnp.float32))

    # -- cached decode -----------------------------------------------------------

    def init_cache(self, params: Params, frames: jax.Array,
                   max_len: int) -> Params:
        """Run the encoder and build (cross K/V + empty self) caches."""
        cfg = self.cfg
        memory = self.encode(params, frames)
        B = frames.shape[0]
        dtype = jnp.dtype(cfg.compute_dtype)
        cache: Params = {}
        for i, spec in enumerate(self.dec_specs):
            c = block_cache_init(spec, B, max_len, dtype)
            c["cross"] = L.cross_memory_cache(
                spec.cross, params[f"dec_{i}"]["cross"], memory)
            cache[f"dec_{i}"] = c
        return cache

    def cache_axes(self) -> dict:
        return {f"dec_{i}": block_cache_axes(spec)
                for i, spec in enumerate(self.dec_specs)}

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    step: jax.Array) -> tuple[jax.Array, Params]:
        cfg, parallel = self.cfg, self.parallel
        B = tokens.shape[0]
        step = jnp.broadcast_to(jnp.asarray(step, jnp.int32), (B,))
        x = L.embed_lookup(params["embed"], tokens, self.dtype)
        # sinusoidal position for each row's current step
        d = cfg.d_model
        ang = (step.astype(jnp.float32)[:, None]
               * jnp.exp(-jnp.log(10000.0) * jnp.arange(d // 2) / (d // 2)))
        pos = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, None]
        x = x + pos.astype(x.dtype)
        x = parallel.shard_batch(x)
        new_cache: Params = {}
        for i, spec in enumerate(self.dec_specs):
            x, new_cache[f"dec_{i}"] = block_decode(
                spec, params[f"dec_{i}"], cache[f"dec_{i}"], x, step, parallel)
        x = L.norm_apply(params["final_norm"], x, cfg.norm)
        logits = L.tied_logits(params["embed"], x)
        return logits, new_cache
