"""Vision Transformer (paper §4.1/§4.2 target): patch embed (dense linear),
bidirectional encoder blocks with structured linears, mean-pool classifier.

Used by the paper-reproduction benchmarks (ViT from-scratch Fig. 4/Table 1,
compression Fig. 6).  Images arrive as (B, n_patches, patch_dim) — the
patchify reshape happens in the data pipeline, keeping the model pure."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.structures import make_linear
from repro.models import layers as L
from repro.models.transformer import block_apply, block_axes, block_init, make_block
from repro.parallel import Parallel, NO_PARALLEL

Params = dict[str, Any]


class ViT:
    def __init__(self, cfg: ArchConfig, patch_dim: int = 768,
                 n_patches: int = 196, parallel: Parallel = NO_PARALLEL):
        self.cfg = cfg
        self.parallel = parallel
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.patch_dim = patch_dim
        self.n_patches = n_patches
        self.patch_proj = make_linear(patch_dim, cfg.d_model, structured=False)
        self.blocks = [make_block(cfg, "attn", causal=False)
                       for _ in range(cfg.n_layers)]
        self.head = make_linear(cfg.d_model, cfg.vocab, structured=False)

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        params: Params = {
            "patch": L.linear_init(self.patch_proj, ks[0], self.dtype, bias=True),
            "pos": (0.02 * jax.random.normal(
                ks[1], (self.n_patches, cfg.d_model))).astype(self.dtype),
            "final_norm": L.norm_init(cfg.d_model, cfg.norm, self.dtype),
            "head": L.linear_init(self.head, ks[2], self.dtype, bias=True),
        }
        for i, spec in enumerate(self.blocks):
            params[f"blk_{i}"] = block_init(
                spec, jax.random.fold_in(ks[3], i), self.dtype, cfg.d_model)
        return params

    def axes(self) -> dict:
        a: dict = {
            "patch": L.linear_axes(self.patch_proj, bias=True),
            "pos": (None, "embed"),
            "final_norm": L.norm_axes(self.cfg.norm),
            "head": {**L.linear_axes(self.head, out_axis="vocab"), "bias": (None,)},
        }
        for i, spec in enumerate(self.blocks):
            a[f"blk_{i}"] = block_axes(spec)
        return a

    def apply(self, params: Params, patches: jax.Array) -> jax.Array:
        """patches: (B, n_patches, patch_dim) → logits (B, n_classes)."""
        cfg, parallel = self.cfg, self.parallel
        x = L.linear_apply(self.patch_proj, params["patch"], patches.astype(self.dtype))
        x = x + params["pos"][None, : x.shape[1]]
        x = parallel.shard_batch(x)
        positions = jnp.arange(x.shape[1])
        for i, spec in enumerate(self.blocks):
            x, _ = block_apply(spec, params[f"blk_{i}"], x, positions, parallel)
        x = L.norm_apply(params["final_norm"], x, cfg.norm)
        pooled = jnp.mean(x, axis=1)
        return L.linear_apply(self.head, params["head"], pooled)
