"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked algorithm: within each length-L chunk the output is computed with the
"dual" quadratic attention form (MXU-friendly batched matmuls); across chunks
a linear recurrence over the (H, P, N) chunk states runs in a lax.scan —
T/L sequential steps of tiny state math.  Decode is the pure recurrent form:
O(1) state update per token, so ``long_500k`` is representable.

The in/out projections are structured (BLAST-able) linears; the SSD scan
itself is attention-free and has no weight matrix — the paper's technique is
*inapplicable to the recurrence*, as recorded in DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import quant as qt
from repro.configs.base import ArchConfig
from repro.core.structures import LinearSpec, make_linear
from repro.models import layers as L
from repro.models.rglru import _conv1d
from repro.parallel import Parallel, NO_PARALLEL

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SSDSpec:
    cfg: ArchConfig
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int
    chunk: int
    conv_width: int
    n_groups: int
    in_proj: LinearSpec   # d -> 2·d_inner + 2·G·N + H   (z, x, B, C, dt)
    out_proj: LinearSpec  # d_inner -> d


def make_ssd(cfg: ArchConfig) -> SSDSpec:
    s = cfg.ssd
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    G = 1
    d_in_proj = 2 * d_inner + 2 * G * s.d_state + n_heads
    return SSDSpec(
        cfg=cfg, d_inner=d_inner, n_heads=n_heads, head_dim=s.head_dim,
        d_state=s.d_state, chunk=s.chunk, conv_width=s.conv_width, n_groups=G,
        in_proj=make_linear(cfg.d_model, d_in_proj, cfg.structure),
        out_proj=make_linear(d_inner, cfg.d_model, cfg.structure),
    )


def ssd_init(spec: SSDSpec, key, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    H = spec.n_heads
    conv_ch = spec.d_inner + 2 * spec.n_groups * spec.d_state
    dt = jnp.exp(jax.random.uniform(k3, (H,), minval=jnp.log(1e-3),
                                    maxval=jnp.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # softplus⁻¹(dt)
    return {
        "in_proj": L.linear_init(spec.in_proj, k1, dtype),
        "out_proj": L.linear_init(spec.out_proj, k2, dtype),
        "conv_w": jnp.zeros((spec.conv_width, conv_ch), dtype=dtype).at[-1].set(1.0),
        "conv_b": jnp.zeros((conv_ch,), dtype=dtype),
        "A_log": jnp.zeros((H,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": {"scale": jnp.zeros((spec.d_inner,), dtype=dtype)},
    }


def ssd_axes(spec: SSDSpec) -> dict:
    return {
        "in_proj": L.linear_axes(spec.in_proj, out_axis="ffn"),
        "out_proj": L.linear_axes(spec.out_proj, in_axis="ffn", out_axis="fsdp_in"),
        "conv_w": (None, "ffn"),
        "conv_b": ("ffn",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": {"scale": ("ffn",)},
    }


def ssd_quantize(spec: SSDSpec, params: Params, bits: int = 8) -> Params:
    """Quantize the structured in/out projections (where the params live);
    conv / gates / norm stay float — they are O(d_inner), not O(d²)."""
    qp = dict(params)
    qp["in_proj"] = L.linear_quantize(spec.in_proj, params["in_proj"], bits)
    qp["out_proj"] = L.linear_quantize(spec.out_proj, params["out_proj"], bits)
    return qp


def _split_in_proj(spec: SSDSpec, zxbcdt: jax.Array):
    d_inner, G, N, H = spec.d_inner, spec.n_groups, spec.d_state, spec.n_heads
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: 2 * d_inner + 2 * G * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * G * N:]
    return z, xBC, dt


def _split_xbc(spec: SSDSpec, xBC: jax.Array):
    d_inner, G, N = spec.d_inner, spec.n_groups, spec.d_state
    x = xBC[..., :d_inner]
    Bm = xBC[..., d_inner: d_inner + G * N]
    Cm = xBC[..., d_inner + G * N:]
    return x, Bm, Cm


def _segsum(da: jax.Array) -> jax.Array:
    """da: (..., L) → (..., L, L) lower-tri matrix of Σ_{j<i≤k} da_k."""
    Ln = da.shape[-1]
    cs = jnp.cumsum(da, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # Σ over (j, i]
    mask = jnp.tril(jnp.ones((Ln, Ln), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int, h0: jax.Array | None = None):
    """Chunked SSD scan (fp32).

    x: (B, T, H, P); dt: (B, T, H); A: (H,); Bm/Cm: (B, T, G, N).
    → y: (B, T, H, P), h_last: (B, H, P, N)
    """
    Bsz, T, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Ln = min(chunk, T)
    pad = (-T) % Ln
    if pad:
        # zero-pad the tail: dt=0 ⇒ decay=1 and x̄=0, so the padded steps
        # neither move the state nor contribute output (sliced off below).
        z = lambda t: jnp.pad(t, [(0, pad if i == 1 else 0)
                                  for i in range(t.ndim)])
        x, dt, Bm, Cm = z(x), z(dt), z(Bm), z(Cm)
        T += pad
    nc = T // Ln
    rep = H // G
    xc = x.reshape(Bsz, nc, Ln, H, Pd)
    dtc = dt.reshape(Bsz, nc, Ln, H)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, Ln, G, N), rep, axis=3)   # (B,nc,L,H,N)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, Ln, G, N), rep, axis=3)
    da = dtc * A[None, None, None, :]                              # (B,nc,L,H)
    xdt = xc * dtc[..., None]                                      # x̄ = dt·x

    # ---- intra-chunk (dual quadratic form)
    Lmat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))              # (B,nc,H,L,L)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)              # (B,nc,H,L,L)
    y_intra = jnp.einsum("bchls,bchls,bcshp->bclhp", scores, Lmat, xdt)

    # ---- chunk states  S_c = Σ_l exp(Σ_{k>l} da) · B_l ⊗ x̄_l
    da_cum = jnp.cumsum(da, axis=2)                                # (B,nc,L,H)
    decay_states = jnp.exp(da_cum[:, :, -1:, :] - da_cum)          # (B,nc,L,H)
    S = jnp.einsum("bclh,bclhn,bclhp->bchpn", decay_states, Bc, xdt)

    # ---- inter-chunk recurrence:  h_c = exp(Σ da_c)·h_{c-1} + S_c
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])                     # (B,nc,H)

    def step(h, inp):
        dec, s = inp
        h_new = dec[:, :, None, None] * h + s
        return h_new, h  # emit state *entering* the chunk

    h_init = jnp.zeros((Bsz, H, Pd, N), jnp.float32) if h0 is None else h0
    h_last, h_prev = jax.lax.scan(
        step, h_init, (chunk_decay.transpose(1, 0, 2), S.transpose(1, 0, 2, 3, 4)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                       # (B,nc,H,P,N)

    # ---- inter-chunk output:  y_l += exp(da_cum_l) · C_l · h_prev
    y_inter = jnp.einsum("bclh,bclhn,bchpn->bclhp",
                         jnp.exp(da_cum), Cc, h_prev)
    y = (y_intra + y_inter).reshape(Bsz, T, H, Pd)
    if pad:
        y = y[:, : T - pad]
    return y, h_last


def ssd_apply(spec: SSDSpec, params: Params, x: jax.Array,
              positions: jax.Array, parallel: Parallel = NO_PARALLEL,
              *, return_cache: bool = False):
    """x: (B, T, d_model) → (B, T, d_model) [, cache]."""
    Bsz, T, _ = x.shape
    H, Pd, N, G = spec.n_heads, spec.head_dim, spec.d_state, spec.n_groups
    zxbcdt = L.linear_apply(spec.in_proj, params["in_proj"], x)
    zxbcdt = parallel.constraint(zxbcdt, parallel.batch_spec(None, None))
    z, xBC_pre, dt_raw = _split_in_proj(spec, zxbcdt)
    xBC = jax.nn.silu(_conv1d(xBC_pre, params["conv_w"], params["conv_b"]))
    xin, Bm, Cm = _split_xbc(spec, xBC)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, h_last = ssd_chunked(
        xin.reshape(Bsz, T, H, Pd).astype(jnp.float32), dt, A,
        Bm.reshape(Bsz, T, G, N).astype(jnp.float32),
        Cm.reshape(Bsz, T, G, N).astype(jnp.float32), spec.chunk)
    y = y + params["D"][None, None, :, None] * xin.reshape(
        Bsz, T, H, Pd).astype(jnp.float32)
    y = y.reshape(Bsz, T, spec.d_inner).astype(x.dtype)
    from repro.models.ops import rms_norm
    y = rms_norm(y * jax.nn.silu(z), params["norm"]["scale"])
    out = L.linear_apply(spec.out_proj, params["out_proj"], y)
    out = parallel.shard_batch(out)
    if not return_cache:
        return out
    K = spec.conv_width
    tail = xBC_pre[:, -(K - 1):] if T >= K - 1 else jnp.pad(
        xBC_pre, ((0, 0), (K - 1 - T, 0), (0, 0)))
    return out, qt.pack_state_cache(spec.cfg.cache_quant,
                                      tail.astype(x.dtype), h_last)


def ssd_cache_init(spec: SSDSpec, batch: int, max_len: int, dtype) -> Params:
    conv_ch = spec.d_inner + 2 * spec.n_groups * spec.d_state
    h_shape = (batch, spec.n_heads, spec.head_dim, spec.d_state)
    c: Params = {}
    if spec.cfg.cache_quant:
        c["conv"] = jnp.zeros((batch, spec.conv_width - 1, conv_ch), jnp.int8)
        c["conv_scale"] = jnp.zeros((batch, spec.conv_width - 1), jnp.bfloat16)
        c["h"] = jnp.zeros(h_shape, jnp.int8)
        c["h_scale"] = jnp.zeros(h_shape[:-1], jnp.float32)
    else:
        c["conv"] = jnp.zeros((batch, spec.conv_width - 1, conv_ch), dtype=dtype)
        c["h"] = jnp.zeros(h_shape, jnp.float32)
    return c


def ssd_cache_axes(spec: SSDSpec) -> dict:
    a = {"conv": ("batch", None, "ffn"), "h": ("batch", None, None, None)}
    if spec.cfg.cache_quant:
        a["conv_scale"] = ("batch", None)
        a["h_scale"] = ("batch", None, None)
    return a


def ssd_prefill(spec: SSDSpec, params: Params, cache: Params, x: jax.Array,
                steps: jax.Array, n_tokens: jax.Array,
                parallel: Parallel = NO_PARALLEL, *,
                collect: bool = False) -> tuple[jax.Array, Params]:
    """Multi-token prefill: batched projections + exact per-token recurrence.

    The structured in/out projections — where the (tokens × rank) BLAST tiles
    and hence the FLOPs live — run over the whole (B, C) chunk; the O(1)
    state update runs in a lax.scan over C, bit-matching C sequential decode
    steps.  Rows are ragged: column i of row b is live iff i < n_tokens[b];
    dead columns neither advance (conv, h) nor contribute (their outputs are
    garbage the engine discards).  ``steps`` is unused (no positional state)
    but kept for the uniform mixer-prefill signature.

    ``collect=True`` additionally returns per-token state snapshots in the
    cache (``h_snap (B, C+1, H, P, N)`` with index 0 = the incoming state,
    plus the full conv history ``conv_hist``) so a speculative verify step
    can be rolled back to any draft boundary (``ssd_cache_rollback``).
    """
    del steps
    Bsz, C, _ = x.shape
    H, Pd, N, G = spec.n_heads, spec.head_dim, spec.d_state, spec.n_groups
    rep = H // G
    conv_prev, h_prev = qt.unpack_state_cache(spec.cfg.cache_quant,
                                              cache, x.dtype)
    zxbcdt = L.linear_apply(spec.in_proj, params["in_proj"], x)
    z, xBC_pre, dt_raw = _split_in_proj(spec, zxbcdt)
    valid = jnp.arange(C)[None, :] < n_tokens[:, None]           # (B, C)

    # Everything except the h recurrence is position-parallel and hoisted
    # out of the scan.
    from repro.models.ops import causal_conv_chunk
    y_conv, conv_f = causal_conv_chunk(conv_prev, xBC_pre,
                                       params["conv_w"], params["conv_b"],
                                       n_tokens)
    xBC = jax.nn.silu(y_conv)
    xin, Bm, Cm = _split_xbc(spec, xBC)
    xin = xin.reshape(Bsz, C, H, Pd).astype(jnp.float32)
    Bm = jnp.repeat(Bm.reshape(Bsz, C, G, N).astype(jnp.float32), rep, axis=2)
    Cm = jnp.repeat(Cm.reshape(Bsz, C, G, N).astype(jnp.float32), rep, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    dt = jnp.where(valid[..., None], dt, 0.0)    # dead cols: a=1, x̄=0 → h fixed
    a = jnp.exp(dt * (-jnp.exp(params["A_log"])))                # (B, C, H)

    def tok(h, inp):
        a_t, dt_t, Bm_t, Cm_t, xin_t = inp
        h_new = (a_t[:, :, None, None] * h
                 + jnp.einsum("bh,bhn,bhp->bhpn", dt_t, Bm_t, xin_t))
        y_t = jnp.einsum("bhn,bhpn->bhp", Cm_t, h_new)
        return h_new, ((y_t, h_new) if collect else y_t)

    h_f, ys = jax.lax.scan(
        tok, h_prev,
        (a.transpose(1, 0, 2), dt.transpose(1, 0, 2),
         Bm.transpose(1, 0, 2, 3), Cm.transpose(1, 0, 2, 3),
         xin.transpose(1, 0, 2, 3)))
    if collect:
        ys, hs = ys
    y = ys.transpose(1, 0, 2, 3) + params["D"][None, None, :, None] * xin
    y = y.reshape(Bsz, C, spec.d_inner).astype(x.dtype)
    from repro.models.ops import rms_norm
    y = rms_norm(y * jax.nn.silu(z), params["norm"]["scale"])
    out = L.linear_apply(spec.out_proj, params["out_proj"], y)
    new_cache = qt.pack_state_cache(spec.cfg.cache_quant, conv_f, h_f)
    if collect:
        new_cache["h_snap"] = jnp.concatenate(
            [h_prev.astype(jnp.float32)[:, None],
             hs.transpose(1, 0, 2, 3, 4)], axis=1)     # (B, C+1, H, P, N)
        new_cache["conv_hist"] = jnp.concatenate([conv_prev, xBC_pre], axis=1)
    return parallel.shard_batch(out), new_cache


def ssd_cache_rollback(spec: SSDSpec, cache: Params,
                       n_comm: jax.Array) -> Params:
    """Rewind a ``collect=True`` prefill's cache to its first ``n_comm``
    tokens.  Dead/rejected columns set dt=0 (a=1, +0 update), so
    ``h_snap[:, n_comm]`` is bit-identical to never having fed the rejected
    tokens; the conv buffer is the K−1 history entries ending at n_comm.
    Re-packing through ``pack_state_cache`` reproduces quantized-cache bits
    too."""
    h_snap, hist = cache["h_snap"], cache["conv_hist"]
    B = h_snap.shape[0]
    K1 = spec.conv_width - 1
    idx = n_comm[:, None] + jnp.arange(K1, dtype=n_comm.dtype)[None, :]
    conv = jnp.take_along_axis(hist, idx[:, :, None], axis=1)
    h = h_snap[jnp.arange(B), n_comm]
    return qt.pack_state_cache(spec.cfg.cache_quant, conv, h)


def ssd_decode(spec: SSDSpec, params: Params, cache: Params, x: jax.Array,
               step: jax.Array, parallel: Parallel = NO_PARALLEL
               ) -> tuple[jax.Array, Params]:
    """Single-token recurrent decode — ``ssd_prefill`` with C=1."""
    Bsz = x.shape[0]
    return ssd_prefill(spec, params, cache, x,
                       jnp.zeros((Bsz,), jnp.int32),
                       jnp.ones((Bsz,), jnp.int32), parallel)
