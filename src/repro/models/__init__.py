"""Model substrate: structure-agnostic layers, mixers, and LM assemblies.

- ``layers``       linears (any structure), norms, GQA + MLA attention, FFN
- ``moe``          top-k MoE with expert-parallel all_to_all dispatch
- ``rglru``        Griffin RG-LRU recurrent block
- ``ssd``          Mamba-2 state-space-duality mixer
- ``transformer``  decoder LM (scan-over-layers, cached decode, MTP)
- ``encdec``       whisper-style encoder-decoder (stub frontend)
- ``ops``          chunked attention, norms, rope, losses
"""

from repro.models.transformer import LM  # noqa: F401
from repro.models.encdec import EncDec  # noqa: F401


def build_model(cfg, parallel=None):
    """Factory: enc-dec archs get EncDec, everything else LM."""
    from repro.parallel import NO_PARALLEL
    parallel = parallel or NO_PARALLEL
    if cfg.encoder is not None:
        return EncDec(cfg, parallel)
    return LM(cfg, parallel)
