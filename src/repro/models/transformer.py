"""Decoder LM assembly: pattern-cycled blocks (attn / local_attn / mla /
rglru / ssd mixers × ffn / moe / none), scan-over-layers with remat,
token or embedding inputs (llava), MTP head (DeepSeek-V3), cached decode.

Layer streaming: ``n_layers`` decomposes into

    [prefix]  unrolled first-k layers (DeepSeek's dense-FFN warmup layers)
    [cycles]  jax.lax.scan over repetitions of the arch's mixer pattern —
              one compiled block per pattern position, params stacked over
              cycles (compile time & HLO size stay O(pattern), not O(L))
    [tail]    unrolled leftover layers when the pattern doesn't divide

The same decomposition drives init, logical axes, cache init and decode.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import structures
from repro.core.structures import make_linear
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ops
from repro.models.rglru import (RGLRUSpec, make_rglru, rglru_apply, rglru_axes,
                                rglru_cache_axes, rglru_cache_init,
                                rglru_cache_rollback, rglru_init,
                                rglru_prefill, rglru_prestack, rglru_quantize)
from repro.models.ssd import (SSDSpec, make_ssd, ssd_apply, ssd_axes,
                              ssd_cache_axes, ssd_cache_init,
                              ssd_cache_rollback, ssd_init, ssd_prefill,
                              ssd_quantize)
from repro.parallel import Parallel, NO_PARALLEL
from repro import quant as qt
from repro.quant import QuantConfig

Params = dict[str, Any]


class Output(NamedTuple):
    logits: jax.Array
    aux: jax.Array              # MoE load-balance loss (0 for non-MoE)
    mtp_logits: jax.Array | None = None


# ---------------------------------------------------------------------------
# Block: one residual layer = mixer (+ optional cross-attn) (+ ffn/moe).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str                   # attn | local_attn | mla | rglru | ssd
    mixer: Any
    ffn: Any | None
    ffn_kind: str               # ffn | moe | none
    norm: str
    cross: L.AttnSpec | None = None


def make_block(cfg: ArchConfig, kind: str, *, moe_layer: bool = False,
               dense_ff_width: int = 0, causal: bool = True,
               cross: bool = False) -> BlockSpec:
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else None
        mixer = L.make_attention(cfg, window=window, causal=causal)
    elif kind == "mla":
        mixer = L.make_mla(cfg)
    elif kind == "rglru":
        mixer = make_rglru(cfg)
    elif kind == "ssd":
        mixer = make_ssd(cfg)
    else:
        raise ValueError(kind)
    if moe_layer:
        ffn, ffn_kind = moe_lib.make_moe(cfg), "moe"
    else:
        width = dense_ff_width or cfg.d_ff
        if width:
            ffn = L.make_ffn(cfg.d_model, width, cfg.ffn_kind, cfg.ffn_structure)
            ffn_kind = "ffn"
        else:
            ffn, ffn_kind = None, "none"
    xspec = L.make_attention(cfg, cross=True) if cross else None
    return BlockSpec(kind=kind, mixer=mixer, ffn=ffn, ffn_kind=ffn_kind,
                     norm=cfg.norm, cross=xspec)


def block_init(spec: BlockSpec, key, dtype, d_model: int) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if spec.kind in ("attn", "local_attn"):
        mixer = L.attn_init(spec.mixer, k1, dtype)
    elif spec.kind == "mla":
        mixer = L.mla_init(spec.mixer, k1, dtype)
    elif spec.kind == "rglru":
        mixer = rglru_init(spec.mixer, k1, dtype)
    else:
        mixer = ssd_init(spec.mixer, k1, dtype)
    p: Params = {"norm1": L.norm_init(d_model, spec.norm, dtype), "mixer": mixer}
    if spec.cross is not None:
        p["norm_x"] = L.norm_init(d_model, spec.norm, dtype)
        p["cross"] = L.attn_init(spec.cross, k4, dtype)
    if spec.ffn_kind == "moe":
        p["norm2"] = L.norm_init(d_model, spec.norm, dtype)
        p["ffn"] = moe_lib.moe_init(spec.ffn, k2, dtype)
    elif spec.ffn_kind == "ffn":
        p["norm2"] = L.norm_init(d_model, spec.norm, dtype)
        p["ffn"] = L.ffn_init(spec.ffn, k2, dtype)
    return p


def block_axes(spec: BlockSpec) -> dict:
    if spec.kind in ("attn", "local_attn"):
        mixer = L.attn_axes(spec.mixer)
    elif spec.kind == "mla":
        mixer = L.mla_axes(spec.mixer)
    elif spec.kind == "rglru":
        mixer = rglru_axes(spec.mixer)
    else:
        mixer = ssd_axes(spec.mixer)
    a = {"norm1": L.norm_axes(spec.norm), "mixer": mixer}
    if spec.cross is not None:
        a["norm_x"] = L.norm_axes(spec.norm)
        a["cross"] = L.attn_axes(spec.cross)
    if spec.ffn_kind == "moe":
        a["norm2"] = L.norm_axes(spec.norm)
        a["ffn"] = moe_lib.moe_axes(spec.ffn)
    elif spec.ffn_kind == "ffn":
        a["norm2"] = L.norm_axes(spec.norm)
        a["ffn"] = L.ffn_axes(spec.ffn)
    return a


def block_quantize(spec: BlockSpec, params: Params, bits: int = 8) -> Params:
    """Quantize a block's structured linears to per-block QArrays (norms
    pass through).  Mirrors ``block_axes``' dispatch over mixer kinds."""
    if spec.kind in ("attn", "local_attn"):
        mixer = L.attn_quantize(spec.mixer, params["mixer"], bits)
    elif spec.kind == "mla":
        mixer = L.mla_quantize(spec.mixer, params["mixer"], bits)
    elif spec.kind == "rglru":
        mixer = rglru_quantize(spec.mixer, params["mixer"], bits)
    else:
        mixer = ssd_quantize(spec.mixer, params["mixer"], bits)
    p = dict(params)
    p["mixer"] = mixer
    if spec.cross is not None:
        p["cross"] = L.attn_quantize(spec.cross, params["cross"], bits)
    if spec.ffn_kind == "moe":
        p["ffn"] = moe_lib.moe_quantize(spec.ffn, params["ffn"], bits)
    elif spec.ffn_kind == "ffn":
        p["ffn"] = L.ffn_quantize(spec.ffn, params["ffn"], bits)
    return p


def block_linear_specs(spec: BlockSpec) -> list:
    """Every structured LinearSpec one block dispatches per step (mixer,
    cross-attention, FFN / MoE experts + shared expert) — the shape registry
    the serving engine feeds to the kernel autotuner."""
    mx = spec.mixer
    if spec.kind in ("attn", "local_attn"):
        specs = [mx.qkv, mx.out]
    elif spec.kind == "mla":
        specs = [mx.wq_a, mx.wq_b, mx.wkv_a, mx.wkv_b, mx.out]
    elif spec.kind == "rglru":
        specs = [mx.in_x, mx.in_gate, mx.out, mx.gate_a, mx.gate_x]
    else:
        specs = [mx.in_proj, mx.out_proj]
    if spec.cross is not None:
        specs += [spec.cross.qkv, spec.cross.out]
    if spec.ffn_kind == "moe":
        specs += [spec.ffn.wi, spec.ffn.wo]
        if spec.ffn.shared is not None:
            specs += [*spec.ffn.shared.in_specs, spec.ffn.shared.wo]
    elif spec.ffn_kind == "ffn":
        specs += [*spec.ffn.in_specs, spec.ffn.wo]
    return specs


def block_apply(spec: BlockSpec, params: Params, x: jax.Array,
                positions: jax.Array, parallel: Parallel,
                memory: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    h = L.norm_apply(params["norm1"], x, spec.norm)
    if spec.kind in ("attn", "local_attn"):
        m = L.attn_apply(spec.mixer, params["mixer"], h, positions, parallel)
    elif spec.kind == "mla":
        m = L.mla_apply(spec.mixer, params["mixer"], h, positions, parallel)
    elif spec.kind == "rglru":
        m = rglru_apply(spec.mixer, params["mixer"], h, positions, parallel)
    else:
        m = ssd_apply(spec.mixer, params["mixer"], h, positions, parallel)
    x = x + m
    if spec.cross is not None:
        h = L.norm_apply(params["norm_x"], x, spec.norm)
        x = x + L.attn_apply(spec.cross, params["cross"], h, positions,
                             parallel, memory=memory)
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn_kind == "moe":
        h = L.norm_apply(params["norm2"], x, spec.norm)
        f, aux = moe_lib.moe_apply(spec.ffn, params["ffn"], h, parallel)
        x = x + f
    elif spec.ffn_kind == "ffn":
        h = L.norm_apply(params["norm2"], x, spec.norm)
        x = x + L.ffn_apply(spec.ffn, params["ffn"], h, parallel)
    # MaxText-style layer-boundary constraint: the residual stream re-enters
    # each block batch-sharded / d-replicated, so GSPMD never speculatively
    # leaves a TP partial-sum layout to flow across blocks
    return parallel.shard_batch(x), aux


def block_cache_init(spec: BlockSpec, batch: int, max_len: int, dtype) -> Params:
    if spec.kind in ("attn", "local_attn"):
        c = {"mixer": L.attn_cache_init(spec.mixer, batch, max_len, dtype)}
    elif spec.kind == "mla":
        c = {"mixer": L.mla_cache_init(spec.mixer, batch, max_len, dtype)}
    elif spec.kind == "rglru":
        c = {"mixer": rglru_cache_init(spec.mixer, batch, max_len, dtype)}
    else:
        c = {"mixer": ssd_cache_init(spec.mixer, batch, max_len, dtype)}
    if spec.cross is not None:
        # placeholder; filled by cross_memory_cache at prefill/encode time
        hq, hkv, hd = spec.cross.dims
        n_mem = 1  # overwritten with real memory length by encdec
        c["cross"] = {"k": jnp.zeros((batch, n_mem, hkv, hd), dtype),
                      "v": jnp.zeros((batch, n_mem, hkv, hd), dtype),
                      "pos": jnp.zeros((n_mem,), jnp.int32)}
    return c


def block_cache_axes(spec: BlockSpec) -> dict:
    if spec.kind in ("attn", "local_attn"):
        a = {"mixer": L.attn_cache_axes(spec.mixer)}
    elif spec.kind == "mla":
        a = {"mixer": L.mla_cache_axes(spec.mixer)}
    elif spec.kind == "rglru":
        a = {"mixer": rglru_cache_axes(spec.mixer)}
    else:
        a = {"mixer": ssd_cache_axes(spec.mixer)}
    if spec.cross is not None:
        a["cross"] = L.attn_cache_axes(spec.cross)
    return a


def block_prefill(spec: BlockSpec, params: Params, cache: Params, x: jax.Array,
                  steps: jax.Array, n_tokens: jax.Array, parallel: Parallel,
                  collect: bool = False) -> tuple[jax.Array, Params]:
    """Multi-token cached step.  x: (B, C, d); steps/n_tokens: (B,) per-slot
    offsets and live token counts (ragged rows — see the mixer prefills).
    ``collect=True`` makes the recurrent mixers (SSD / RG-LRU) return
    per-token state snapshots in their cache for speculative rollback (the
    KV families rewind by position and need no snapshots)."""
    h = L.norm_apply(params["norm1"], x, spec.norm)
    new_cache = dict(cache)
    if spec.kind in ("attn", "local_attn"):
        m, new_cache["mixer"] = L.attn_prefill(
            spec.mixer, params["mixer"], cache["mixer"], h, steps, n_tokens,
            parallel)
    elif spec.kind == "mla":
        m, new_cache["mixer"] = L.mla_prefill(
            spec.mixer, params["mixer"], cache["mixer"], h, steps, n_tokens,
            parallel)
    elif spec.kind == "rglru":
        m, new_cache["mixer"] = rglru_prefill(
            spec.mixer, params["mixer"], cache["mixer"], h, steps, n_tokens,
            parallel, collect=collect)
    else:
        m, new_cache["mixer"] = ssd_prefill(
            spec.mixer, params["mixer"], cache["mixer"], h, steps, n_tokens,
            parallel, collect=collect)
    x = x + m
    if spec.cross is not None:
        h = L.norm_apply(params["norm_x"], x, spec.norm)
        m, _ = L.attn_prefill(spec.cross, params["cross"], cache["cross"], h,
                              steps, n_tokens, parallel)
        x = x + m
    if spec.ffn_kind == "moe":
        h = L.norm_apply(params["norm2"], x, spec.norm)
        f, _ = moe_lib.moe_apply(spec.ffn, params["ffn"], h, parallel)
        x = x + f
    elif spec.ffn_kind == "ffn":
        h = L.norm_apply(params["norm2"], x, spec.norm)
        x = x + L.ffn_apply(spec.ffn, params["ffn"], h, parallel)
    return parallel.shard_batch(x), new_cache


def block_decode(spec: BlockSpec, params: Params, cache: Params, x: jax.Array,
                 step: jax.Array, parallel: Parallel
                 ) -> tuple[jax.Array, Params]:
    """Single-token cached step — ``block_prefill`` with C=1."""
    B = x.shape[0]
    step = jnp.broadcast_to(jnp.asarray(step, jnp.int32), (B,))
    return block_prefill(spec, params, cache, x, step,
                         jnp.ones((B,), jnp.int32), parallel)


def block_cache_rollback(spec: BlockSpec, old: Params, new: Params,
                         steps: jax.Array, n_comm: jax.Array) -> Params:
    """Rewind one block's cache after a speculative verify chunk: keep the
    first ``n_comm`` tokens written at ``steps``, revert the rest.  ``old``
    is the pre-verify cache (needed by the KV families — a ring-buffer write
    from a rejected draft may have clobbered a still-live slot); ``new`` is
    the verify chunk's ``collect_states=True`` output (carries the recurrent
    families' snapshots).  The result drops the snapshot leaves, matching
    the ``block_cache_init`` tree."""
    out = dict(new)  # cross-attn memories are static; pass through
    if spec.kind in ("attn", "local_attn", "mla"):
        out["mixer"] = L.kv_cache_rollback(old["mixer"], new["mixer"],
                                           steps, n_comm)
    elif spec.kind == "rglru":
        out["mixer"] = rglru_cache_rollback(spec.mixer, new["mixer"], n_comm)
    else:
        out["mixer"] = ssd_cache_rollback(spec.mixer, new["mixer"], n_comm)
    return out


def block_prestack(spec: BlockSpec, params: Params) -> Params:
    """Pre-stack every grouped projection bundle a block dispatches (MLA
    a-projections, RG-LRU in/gate pairs, SwiGLU gate+up incl. the MoE shared
    expert) once at engine load — see ``structures.prestack``."""
    p = dict(params)
    if spec.kind == "mla":
        p["mixer"] = L.mla_prestack(spec.mixer, params["mixer"])
    elif spec.kind == "rglru":
        p["mixer"] = rglru_prestack(spec.mixer, params["mixer"])
    if spec.ffn_kind == "moe":
        p["ffn"] = moe_lib.moe_prestack(spec.ffn, params["ffn"])
    elif spec.ffn_kind == "ffn":
        p["ffn"] = L.ffn_prestack(spec.ffn, params["ffn"])
    return p


# -- nested-rank draft models (self-speculative decoding) --------------------


def _is_rank_linear(t) -> bool:
    return structures.rank_kind(t) is not None


def _vmap_depth(lin: Params) -> int:
    """Leading stacked axes on a rank-bearing linear's factors (0 normally,
    1 for vmap-stacked MoE expert params)."""
    probe = lin["U"] if "U" in lin else lin["w_down"]
    base = 3 if "U" in lin else 2
    return len(probe.shape) - base


def _collect_spectra(tree, path: str = "") -> dict:
    """path → rank_spectrum for every rank-bearing linear in a params tree.
    Stacked-expert linears vmap the spectrum and average over the expert
    axis (truncation must be uniform there to keep stacked shapes)."""
    if _is_rank_linear(tree):
        fn = structures.rank_spectrum
        for _ in range(_vmap_depth(tree)):
            fn = jax.vmap(fn)
        e = fn(tree)
        while e.ndim > 1:
            e = jnp.mean(e, axis=0)
        return {path: e}
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out.update(_collect_spectra(v, f"{path}.{k}" if path else k))
        return out
    return {}


def _truncate_tree(tree, plan: dict, path: str = ""):
    """Apply a {path: r'} truncation plan to a params tree (stacked-expert
    linears truncate under vmap: per-expert component choices, uniform r')."""
    if _is_rank_linear(tree):
        r = plan.get(path)
        if r is None:
            return tree
        fn = lambda p: structures.truncate_rank(p, r)
        for _ in range(_vmap_depth(tree)):
            fn = jax.vmap(fn)
        return fn(tree)
    if isinstance(tree, dict):
        return {k: _truncate_tree(v, plan, f"{path}.{k}" if path else k)
                for k, v in tree.items()}
    return tree


# ---------------------------------------------------------------------------
# The language model.
# ---------------------------------------------------------------------------


class LM:
    """Decoder-only LM over any ArchConfig (all assigned non-enc-dec archs)."""

    def __init__(self, cfg: ArchConfig, parallel: Parallel = NO_PARALLEL):
        self.cfg = cfg
        self.parallel = parallel
        self.dtype = jnp.dtype(cfg.param_dtype)
        kinds = cfg.layer_kinds()
        n_prefix = cfg.moe.first_dense_layers if cfg.moe else 0
        self.prefix_specs = [
            make_block(cfg, kinds[i], dense_ff_width=cfg.moe.dense_d_ff)
            for i in range(n_prefix)]
        rest = kinds[n_prefix:]
        plen = len(cfg.pattern)
        self.n_cycles = len(rest) // plen if cfg.scan_layers else 0
        cyc, tail = rest[: self.n_cycles * plen], rest[self.n_cycles * plen:]
        if self.n_cycles:
            template = cyc[:plen]
            assert all(cyc[i * plen:(i + 1) * plen] == template
                       for i in range(self.n_cycles)), "pattern must tile"
            self.cycle_specs = [make_block(cfg, k, moe_layer=bool(cfg.moe))
                                for k in template]
        else:
            self.cycle_specs = []
            tail = rest
        self.tail_specs = [make_block(cfg, k, moe_layer=bool(cfg.moe))
                           for k in tail]
        self.head = make_linear(cfg.d_model, cfg.vocab, structured=False)
        if cfg.mtp:
            self.mtp_proj = make_linear(2 * cfg.d_model, cfg.d_model,
                                        structured=False)
            self.mtp_spec = make_block(cfg, kinds[-1], moe_layer=bool(cfg.moe))

    # -- init / axes ---------------------------------------------------------

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: Params = {
            "embed": (0.02 * jax.random.normal(
                keys[0], (cfg.vocab, cfg.d_model))).astype(self.dtype),
            "final_norm": L.norm_init(cfg.d_model, cfg.norm, self.dtype),
        }
        if cfg.pos_embed == "learned":
            params["pos"] = (0.02 * jax.random.normal(
                keys[7], (cfg.max_seq, cfg.d_model))).astype(self.dtype)
        if not cfg.tie_embeddings:
            params["head"] = L.linear_init(
                self.head, keys[1], self.dtype, scale=0.02)
        for i, spec in enumerate(self.prefix_specs):
            params[f"pre_{i}"] = block_init(
                spec, jax.random.fold_in(keys[2], i), self.dtype, cfg.d_model)
        if self.n_cycles:
            def cycle_init(k):
                return {f"blk_{j}": block_init(
                    spec, jax.random.fold_in(k, j), self.dtype, cfg.d_model)
                    for j, spec in enumerate(self.cycle_specs)}
            params["cycles"] = jax.vmap(cycle_init)(
                jax.random.split(keys[3], self.n_cycles))
        for i, spec in enumerate(self.tail_specs):
            params[f"tail_{i}"] = block_init(
                spec, jax.random.fold_in(keys[4], i), self.dtype, cfg.d_model)
        if cfg.mtp:
            params["mtp"] = {
                "proj": L.linear_init(self.mtp_proj, keys[5], self.dtype),
                "norm": L.norm_init(cfg.d_model, cfg.norm, self.dtype),
                "block": block_init(self.mtp_spec, keys[6], self.dtype,
                                    cfg.d_model),
            }
        return params

    def axes(self) -> dict:
        cfg = self.cfg
        a: dict = {
            "embed": ("vocab", "embed"),
            "final_norm": L.norm_axes(cfg.norm),
        }
        if cfg.pos_embed == "learned":
            a["pos"] = (None, "embed")
        if not cfg.tie_embeddings:
            a["head"] = {"w": ("embed", "vocab")}
        for i, spec in enumerate(self.prefix_specs):
            a[f"pre_{i}"] = block_axes(spec)
        if self.n_cycles:
            cyc = {f"blk_{j}": block_axes(spec)
                   for j, spec in enumerate(self.cycle_specs)}
            a["cycles"] = jax.tree.map(
                lambda ax: ("layers",) + ax, cyc,
                is_leaf=lambda t: isinstance(t, tuple))
        for i, spec in enumerate(self.tail_specs):
            a[f"tail_{i}"] = block_axes(spec)
        if cfg.mtp:
            a["mtp"] = {"proj": {"w": (None, None)},
                        "norm": L.norm_axes(cfg.norm),
                        "block": block_axes(self.mtp_spec)}
        return a

    def quantize_params(self, params: Params, quant: QuantConfig) -> Params:
        """Quantize-at-load: every structured linear (and the untied vocab
        head) becomes a per-block QArray; embeddings and norms stay float.
        Scan-stacked cycle params quantize under vmap — the per-cycle
        QArray trees stack on the layers axis like any other params."""
        bits = quant.weight_bits
        if bits is None:
            return params
        cfg = self.cfg
        qp = dict(params)
        # per-row embedding quantization: the gather and the tied head both
        # fuse the per-row scale (embed_lookup / tied_logits)
        qp["embed"] = qt.quantize(params["embed"], bits=bits, block_axes=(1,))
        if not cfg.tie_embeddings:
            qp["head"] = L.linear_quantize(self.head, params["head"], bits)
        for i, spec in enumerate(self.prefix_specs):
            qp[f"pre_{i}"] = block_quantize(spec, params[f"pre_{i}"], bits)
        if self.n_cycles:
            def cycle_quantize(p):
                return {f"blk_{j}": block_quantize(spec, p[f"blk_{j}"], bits)
                        for j, spec in enumerate(self.cycle_specs)}
            qp["cycles"] = jax.vmap(cycle_quantize)(params["cycles"])
        for i, spec in enumerate(self.tail_specs):
            qp[f"tail_{i}"] = block_quantize(spec, params[f"tail_{i}"], bits)
        if cfg.mtp:
            qp["mtp"] = {
                "proj": L.linear_quantize(self.mtp_proj,
                                          params["mtp"]["proj"], bits),
                "norm": params["mtp"]["norm"],
                "block": block_quantize(self.mtp_spec,
                                        params["mtp"]["block"], bits),
            }
        return qp

    def prestack_params(self, params: Params) -> Params:
        """Pre-stack every grouped projection bundle once at load: the
        stacked factor arrays ride inside the param tree as ``GroupBundle``
        pytrees, and the per-step grouped apply skips its pad+stack work
        (``structures.stack_count`` stays 0 per step).  Run this LAST —
        after quantization and any rank truncation — since both change the
        factors a bundle caches (a stale bundle is ignored, not wrong)."""
        pp = dict(params)
        for i, spec in enumerate(self.prefix_specs):
            pp[f"pre_{i}"] = block_prestack(spec, params[f"pre_{i}"])
        if self.n_cycles:
            def one(p):
                return {f"blk_{j}": block_prestack(spec, p[f"blk_{j}"])
                        for j, spec in enumerate(self.cycle_specs)}
            pp["cycles"] = jax.vmap(one)(params["cycles"])
        for i, spec in enumerate(self.tail_specs):
            pp[f"tail_{i}"] = block_prestack(spec, params[f"tail_{i}"])
        return pp

    # -- nested-rank drafts (self-speculative decoding) ----------------------

    def rank_spectra(self, params: Params) -> dict:
        """name → per-component energy spectrum for every rank-bearing
        linear (blast / low_rank).  Scan-stacked cycles average over the
        layer axis (one pattern-position spec serves all cycles, so its
        truncated rank must be uniform); MoE experts likewise."""
        rest = {k: v for k, v in params.items() if k != "cycles"}
        out = _collect_spectra(rest)
        if "cycles" in params:
            cyc = jax.vmap(
                lambda p: _collect_spectra({"cycles": p}))(params["cycles"])
            out.update({k: jnp.mean(v, axis=0) for k, v in cyc.items()})
        return out

    def draft_plan(self, params: Params, frac: float) -> dict:
        """Calibrate per-layer draft ranks from the factor spectra: keep the
        globally highest-energy ~``frac`` of the pooled rank budget (see
        ``core/compress.py::calibrate_ranks``).  Eager (numpy) — run once at
        engine load."""
        from repro.core.compress import calibrate_ranks
        spectra = jax.jit(self.rank_spectra)(params)
        return calibrate_ranks(
            {k: np.asarray(v) for k, v in spectra.items()}, frac)

    def truncate_params(self, params: Params, plan: dict) -> Params:
        """Build the draft model: truncate every planned linear to its r'.
        Shares no new weight storage conceptually — the draft factors are
        column subsets of the full ones (the paper's nesting property); the
        unmodified apply paths read ranks from array shapes."""
        out = _truncate_tree(
            {k: v for k, v in params.items() if k != "cycles"}, plan)
        if "cycles" in params:
            out["cycles"] = jax.vmap(
                lambda p: _truncate_tree({"cycles": p}, plan)["cycles"]
            )(params["cycles"])
        return out

    def rollback_cache(self, old: Params, new: Params, steps: jax.Array,
                       n_comm: jax.Array) -> Params:
        """Rewind a ``collect_states=True`` verify chunk to its first
        ``n_comm[b]`` tokens per row — bit-identical to having fed exactly
        those tokens.  ``old`` is the pre-verify cache; the result matches
        the ``init_cache`` tree (snapshots dropped)."""
        steps = jnp.asarray(steps, jnp.int32)
        n_comm = jnp.asarray(n_comm, jnp.int32)
        out: Params = {}
        for i, spec in enumerate(self.prefix_specs):
            out[f"pre_{i}"] = block_cache_rollback(
                spec, old[f"pre_{i}"], new[f"pre_{i}"], steps, n_comm)
        if self.n_cycles:
            def roll(oc, nc):
                return {f"blk_{j}": block_cache_rollback(
                    spec, oc[f"blk_{j}"], nc[f"blk_{j}"], steps, n_comm)
                    for j, spec in enumerate(self.cycle_specs)}
            out["cycles"] = jax.vmap(roll)(old["cycles"], new["cycles"])
        for i, spec in enumerate(self.tail_specs):
            out[f"tail_{i}"] = block_cache_rollback(
                spec, old[f"tail_{i}"], new[f"tail_{i}"], steps, n_comm)
        return out

    def linear_specs(self) -> list:
        """All structured LinearSpecs the model dispatches (layer-unique:
        scan cycles contribute one copy per pattern position).  Consumed by
        ``serve/engine.py`` to warm the kernel autotune cache at build."""
        specs = []
        for s in (*self.prefix_specs, *self.cycle_specs, *self.tail_specs):
            specs += block_linear_specs(s)
        if not self.cfg.tie_embeddings:
            specs.append(self.head)
        if self.cfg.mtp:
            specs += [self.mtp_proj, *block_linear_specs(self.mtp_spec)]
        return specs

    # -- forward --------------------------------------------------------------

    def _embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        x = L.embed_lookup(params["embed"], tokens, self.dtype,
                           self.parallel)
        if self.cfg.embed_scale:
            x = x * jnp.sqrt(float(self.cfg.d_model)).astype(x.dtype)
        return x

    def _head(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = L.norm_apply(params["final_norm"], x, cfg.norm)
        if cfg.tie_embeddings:
            logits = L.tied_logits(params["embed"], x)
        else:
            logits = L.linear_apply(self.head, params["head"], x)
        logits = self.parallel.constraint(
            logits, self.parallel.batch_spec(None, self.parallel.model_axis))
        return ops.softcap(logits, cfg.logit_softcap)

    def apply(self, params: Params, tokens: jax.Array | None = None,
              embeds: jax.Array | None = None, *,
              last_only: bool = False) -> Output:
        """Full-sequence forward (training / prefill).

        tokens: (B, T) int32 — or embeds: (B, T, d) for stub-frontend archs.
        ``last_only`` projects logits for the final position only (serving
        prefill: no point computing a 32k×V logit tensor to sample 1 token).
        """
        cfg, parallel = self.cfg, self.parallel
        if embeds is None:
            x = self._embed(params, tokens)
        else:
            x = embeds.astype(self.dtype)
        T = x.shape[1]
        if cfg.pos_embed == "learned":
            x = x + params["pos"][:T][None]
        elif cfg.pos_embed == "sinusoidal":
            x = x + ops.sinusoidal_positions(T, cfg.d_model).astype(x.dtype)[None]
        x = parallel.shard_batch(x)
        positions = jnp.arange(T)
        aux = jnp.zeros((), jnp.float32)

        for i, spec in enumerate(self.prefix_specs):
            x, a = block_apply(spec, params[f"pre_{i}"], x, positions, parallel)
            aux += a

        if self.n_cycles:
            def cycle(x, p):
                a_tot = jnp.zeros((), jnp.float32)
                for j, spec in enumerate(self.cycle_specs):
                    x, a = block_apply(spec, p[f"blk_{j}"], x, positions, parallel)
                    a_tot += a
                return x, a_tot
            if cfg.remat:
                cycle = jax.checkpoint(cycle)
            x, auxs = jax.lax.scan(cycle, x, params["cycles"])
            aux += jnp.sum(auxs)

        for i, spec in enumerate(self.tail_specs):
            x, a = block_apply(spec, params[f"tail_{i}"], x, positions, parallel)
            aux += a

        logits = self._head(params, x[:, -1:] if last_only else x)

        mtp_logits = None
        if cfg.mtp and tokens is not None and not last_only:
            # DeepSeek-V3 MTP: one extra block predicting token t+2 from
            # (h_t, embed(t+1)); lm_head shared.
            nxt = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
            h = jnp.concatenate(
                [L.norm_apply(params["mtp"]["norm"], x, cfg.norm),
                 self._embed(params, nxt)], axis=-1)
            h = L.linear_apply(self.mtp_proj, params["mtp"]["proj"], h)
            h, _ = block_apply(self.mtp_spec, params["mtp"]["block"], h,
                               positions, parallel)
            mtp_logits = self._head(params, h)
        return Output(logits=logits, aux=aux, mtp_logits=mtp_logits)

    # -- cached decode ---------------------------------------------------------

    def init_cache(self, batch: int, max_len: int) -> Params:
        dtype = jnp.dtype(self.cfg.compute_dtype)
        cache: Params = {}
        for i, spec in enumerate(self.prefix_specs):
            cache[f"pre_{i}"] = block_cache_init(spec, batch, max_len, dtype)
        if self.n_cycles:
            def one(_):
                return {f"blk_{j}": block_cache_init(spec, batch, max_len, dtype)
                        for j, spec in enumerate(self.cycle_specs)}
            cache["cycles"] = jax.vmap(one)(jnp.arange(self.n_cycles))
        for i, spec in enumerate(self.tail_specs):
            cache[f"tail_{i}"] = block_cache_init(spec, batch, max_len, dtype)
        return cache

    def cache_axes(self) -> dict:
        a: dict = {}
        for i, spec in enumerate(self.prefix_specs):
            a[f"pre_{i}"] = block_cache_axes(spec)
        if self.n_cycles:
            cyc = {f"blk_{j}": block_cache_axes(spec)
                   for j, spec in enumerate(self.cycle_specs)}
            a["cycles"] = jax.tree.map(
                lambda ax: ("layers",) + ax, cyc,
                is_leaf=lambda t: isinstance(t, tuple))
        for i, spec in enumerate(self.tail_specs):
            a[f"tail_{i}"] = block_cache_axes(spec)
        return a

    def prefill_chunk(self, params: Params, cache: Params, tokens: jax.Array,
                      steps: jax.Array, n_tokens: jax.Array | None = None,
                      *, all_logits: bool = False, collect_states: bool = False
                      ) -> tuple[jax.Array, Params]:
        """Multi-token cached step — the unified serving entry point.

        tokens: (B, C) int32; steps: (B,) absolute position of each slot's
        first token; n_tokens: (B,) live tokens per row (defaults to C).
        Rows are ragged: row b consumes tokens[b, :n_tokens[b]], writing its
        KV/state caches at offsets steps[b]..steps[b]+n_tokens[b]; trailing
        columns are padding (no cache/state effect).  Returns
        (logits (B, 1, V), new cache) — the vocab head runs only on each
        row's final live column (serving samples exactly one token per row
        per step; projecting all C columns would waste ~C× head FLOPs).
        C=1 with n_tokens=1 is exactly a decode step, so one jitted instance
        per chunk width C serves mixed prefill+decode batches
        (chunked-prefill continuous batching).

        Speculative-verify knobs (both static): ``all_logits=True`` heads
        every column — logits (B, C, V), column i predicting the token at
        position steps+i+1 — so one call scores k drafts at once;
        ``collect_states=True`` adds per-token recurrent-state snapshots to
        the SSD / RG-LRU caches for ``rollback_cache``.
        """
        cfg, parallel = self.cfg, self.parallel
        B, C = tokens.shape
        steps = jnp.asarray(steps, jnp.int32)
        if n_tokens is None:
            n_tokens = jnp.full((B,), C, jnp.int32)
        n_tokens = jnp.asarray(n_tokens, jnp.int32)
        x = self._embed(params, tokens)
        if cfg.pos_embed == "learned":
            q_pos = steps[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
            x = x + params["pos"][jnp.clip(q_pos, 0, cfg.max_seq - 1)]
        x = parallel.shard_batch(x)
        new_cache: Params = {}
        for i, spec in enumerate(self.prefix_specs):
            x, new_cache[f"pre_{i}"] = block_prefill(
                spec, params[f"pre_{i}"], cache[f"pre_{i}"], x, steps,
                n_tokens, parallel, collect_states)
        if self.n_cycles:
            def cycle(x, pc):
                p, c = pc
                new_c = {}
                for j, spec in enumerate(self.cycle_specs):
                    x, new_c[f"blk_{j}"] = block_prefill(
                        spec, p[f"blk_{j}"], c[f"blk_{j}"], x, steps,
                        n_tokens, parallel, collect_states)
                return x, new_c
            x, new_cache["cycles"] = jax.lax.scan(
                cycle, x, (params["cycles"], cache["cycles"]))
        for i, spec in enumerate(self.tail_specs):
            x, new_cache[f"tail_{i}"] = block_prefill(
                spec, params[f"tail_{i}"], cache[f"tail_{i}"], x, steps,
                n_tokens, parallel, collect_states)
        if not all_logits:
            last = jnp.clip(n_tokens - 1, 0, C - 1)[:, None, None]
            x = jnp.take_along_axis(x, jnp.broadcast_to(
                last, (B, 1, x.shape[-1])), axis=1)   # (B, 1, d)
        logits = self._head(params, x)
        return logits, new_cache

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    step: jax.Array) -> tuple[jax.Array, Params]:
        """One decode step.  tokens: (B, 1) int32; step: scalar or (B,)
        positions.  Returns (logits (B, 1, V), new cache).  Thin wrapper:
        ``prefill_chunk`` with C=1."""
        B = tokens.shape[0]
        step = jnp.broadcast_to(jnp.asarray(step, jnp.int32), (B,))
        return self.prefill_chunk(params, cache, tokens, step,
                                  jnp.ones((B,), jnp.int32))
