"""Structure-agnostic model layers: linears (dense or BLAST or any baseline),
norms, GQA attention (train/prefill/decode), MLA attention (DeepSeek-V3,
latent-cache decode with absorbed up-projections), FFNs.

Every layer is a pair of pure functions:

    init(key, ...) -> params (dict pytree)
    apply(params, x, ...) -> y

plus an ``axes(...)`` function returning a matching pytree of *logical axis
name* tuples, consumed by launch/sharding.py.  ``tests/test_models.py``
asserts init/axes tree congruence for every architecture.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro import quant as qt
from repro.configs.base import ArchConfig, MLACfg
from repro.core import structures
from repro.core.structures import LinearSpec, StructureConfig, make_linear
from repro.parallel import NO_PARALLEL
from repro.models import ops
from repro.parallel import Parallel, NO_PARALLEL

Params = dict[str, Any]
Axes = dict[str, Any]


# ---------------------------------------------------------------------------
# Linear layers (structured or dense) with logical-axis metadata.
# ---------------------------------------------------------------------------


def linear_init(spec: LinearSpec, key, dtype, *, scale=None, bias: bool = False) -> Params:
    p = spec.init(key, dtype=dtype, scale=scale)
    if bias:
        p["bias"] = jnp.zeros((spec.d_out,), dtype=dtype)
    return p


def linear_apply(spec: LinearSpec, params: Params, x: jax.Array) -> jax.Array:
    """Storage-format-aware apply: QArray params route to the structure's
    fused-dequant ``apply_q`` path, float params to the plain ``apply``."""
    structures.record_dispatch(1)
    if any(qt.is_qarray(v) for v in params.values()):
        y = spec.apply_q(params, x)
    else:
        y = spec.apply(params, x)
    if "bias" in params:
        y = y + params["bias"]
    return y


def linear_group_apply(specs: Sequence[LinearSpec],
                       params_list: Sequence[Params],
                       x: jax.Array, bundle=None) -> list[jax.Array]:
    """Apply several linears that share the input ``x``, collapsing
    shape-congruent bundles (gate+up, MLA a-projections, …) into ONE grouped
    matmul launch (``core/structures.py::group_apply`` → the grouped Pallas
    kernels / batched einsum chain).  All-int4 bundles group too — they
    stack packed and dispatch the grouped q4 kernel.  Non-congruent or
    mixed-storage bundles fall back to the per-projection loop — numerics
    are identical either way (the grouped kernel oracle-matches the loop).

    ``bundle``: an optional pre-stacked ``structures.GroupBundle`` (built
    once at engine load by ``prestack``); when its plan matches the live
    plan the per-step factor stacking is skipped.  A stale bundle (params
    re-quantized or rank-truncated after pre-stacking) mismatches and is
    ignored."""
    plan = structures.group_plan(specs, params_list)
    if plan is None:
        return [linear_apply(s, p, x) for s, p in zip(specs, params_list)]
    core = [{k: v for k, v in p.items() if k != "bias"} for p in params_list]
    stacked = None
    if isinstance(bundle, structures.GroupBundle) and bundle.plan == plan:
        stacked = bundle.arrays
    ys = structures.group_apply(specs, core, x, plan=plan, stacked=stacked)
    return [y + p["bias"] if "bias" in p else y
            for y, p in zip(ys, params_list)]


def linear_group_prestack(specs: Sequence[LinearSpec],
                          params_list: Sequence[Params]):
    """Load-time counterpart of ``linear_group_apply``: pre-stack a bundle's
    factors once (None if the bundle is not groupable)."""
    return structures.prestack(specs, params_list)


def linear_quantize(spec: LinearSpec, params: Params, bits: int = 8) -> Params:
    """Quantize a linear's structure params to per-block QArrays (bias, if
    any, stays float — it is O(d_out) and added post-matmul)."""
    qp = spec.quantize({k: v for k, v in params.items() if k != "bias"}, bits)
    if "bias" in params:
        qp["bias"] = params["bias"]
    return qp


def linear_axes(spec: LinearSpec, *, bias: bool = False,
                out_axis: str = "model_out", in_axis: str = "fsdp_in") -> Axes:
    """Logical axes for a linear's params.

    Structured kinds carry their own logical names from structures.py; the
    dense kind maps (in, out) -> (in_axis, out_axis).  ``rank`` (BLAST r,
    low-rank t, monarch k) is the TP-sharded dimension.
    """
    ax: Axes = {}
    for name, axes_tuple in spec.logical_axes.items():
        mapped = []
        for a in axes_tuple:
            if a == "in":
                mapped.append(in_axis)
            elif a == "out":
                mapped.append(out_axis)
            else:
                mapped.append(a)
        ax[name] = tuple(mapped)
    if bias:
        ax["bias"] = (None,)
    return ax


def embed_lookup(table, tokens: jax.Array, dtype,
                 parallel=NO_PARALLEL) -> jax.Array:
    """Token-embedding gather over a float or per-row-quantized table.

    Quantized tables gather the *packed* rows first (int4 rows stay nibble-
    packed through the gather), then dequantize only the (B, C) gathered
    rows — the full float table is never materialized.

    Under a TP mesh the table is vocab-sharded, and GSPMD lowers a plain
    gather to an all-gather of the WHOLE table per step.  The one-hot path
    contracts an i32 one-hot against the packed byte rows instead (a
    row-parallel matmul: each shard selects its local vocab rows, one psum
    combines) — gather-then-dequant-rows with collective bytes ∝ gathered
    rows, not table size.  Byte selection through an integer matmul is
    exact, so both paths return bit-identical rows."""
    if not qt.is_qarray(table):
        return table[tokens]
    if parallel.active and parallel.tp_size > 1:
        vocab = table.q.shape[0]
        hot = jax.nn.one_hot(tokens, vocab, dtype=jnp.int32)
        rows = jnp.einsum("...v,vp->...p", hot,
                          table.q.astype(jnp.int32)).astype(table.q.dtype)
        srows = jnp.einsum("...v,v->...", hot.astype(jnp.float32),
                           table.scale[:, 0].astype(jnp.float32))[..., None]
    else:
        rows = table.q[tokens]
        srows = table.scale[tokens]
    if table.bits == 4:
        rows = qt.unpack_int4(rows, table.last_dim)
    return (rows.astype(jnp.float32) * srows).astype(dtype)


def tied_logits(table, x: jax.Array) -> jax.Array:
    """``x @ embedᵀ`` for a float or per-row-quantized embedding table.

    Per-row scales are constant along the contracted d_model axis, so
    dequantization fuses after the matmul (one multiply per logit)."""
    if not qt.is_qarray(table):
        return x @ table.T
    iv = qt.int_values(table)                        # (vocab, d)
    return ((x @ iv.T.astype(x.dtype)) * table.scale[:, 0]).astype(x.dtype)


def linear_dense_matrix(spec: LinearSpec, params: Params) -> jax.Array:
    """Materialize the (d_in, d_out) dense matrix of any structured linear.

    Used by MLA decode to absorb up-projections; cost O(d_in · flops/token).
    Works on quantized params too (routes through the apply_q path).
    """
    p0 = params[next(iter(spec.shapes))]
    dtype = p0.scale.dtype if qt.is_qarray(p0) else p0.dtype
    eye = jnp.eye(spec.d_in, dtype=dtype)
    return linear_apply(spec, {k: v for k, v in params.items() if k != "bias"},
                        eye)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------


def norm_init(d: int, kind: str, dtype) -> Params:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype=dtype)}
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def norm_apply(params: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return ops.rms_norm(x, params["scale"])
    return ops.layer_norm(x, params["scale"], params["bias"])


def norm_axes(kind: str) -> Axes:
    if kind == "rmsnorm":
        return {"scale": (None,)}
    return {"scale": (None,), "bias": (None,)}


# ---------------------------------------------------------------------------
# GQA attention (full or sliding-window; train / prefill / cached decode).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    cfg: ArchConfig
    window: int | None  # None = full attention
    qkv: LinearSpec
    out: LinearSpec
    cross: bool = False  # whisper decoder cross-attention
    causal: bool = True  # False for encoder self-attention

    @property
    def dims(self) -> tuple[int, int, int]:
        c = self.cfg
        return c.n_heads, c.n_kv_heads, c.head_dim_


def make_attention(cfg: ArchConfig, *, window: int | None = None,
                   cross: bool = False, causal: bool = True) -> AttnSpec:
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    d_qkv = (hq + 2 * hkv) * hd
    # Paper §C.2: q/k/v weights stacked and modeled by ONE structured matrix.
    qkv = make_linear(cfg.d_model, d_qkv, cfg.structure)
    out = make_linear(hq * hd, cfg.d_model, cfg.structure)
    return AttnSpec(cfg=cfg, window=window, qkv=qkv, out=out, cross=cross,
                    causal=causal)


def attn_init(spec: AttnSpec, key, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "qkv": linear_init(spec.qkv, k1, dtype, bias=spec.cfg.qkv_bias),
        "out": linear_init(spec.out, k2, dtype,
                           scale=1.0 / math.sqrt(2 * spec.cfg.n_layers * spec.out.d_in)),
    }


def attn_axes(spec: AttnSpec) -> Axes:
    return {
        "qkv": linear_axes(spec.qkv, bias=spec.cfg.qkv_bias, out_axis="heads"),
        "out": linear_axes(spec.out, in_axis="heads", out_axis="fsdp_in"),
    }


def attn_quantize(spec: AttnSpec, params: Params, bits: int = 8) -> Params:
    return {"qkv": linear_quantize(spec.qkv, params["qkv"], bits),
            "out": linear_quantize(spec.out, params["out"], bits)}


def _split_qkv(spec: AttnSpec, qkv: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    hq, hkv, hd = spec.dims
    *lead, _ = qkv.shape
    q = qkv[..., : hq * hd].reshape(*lead, hq, hd)
    k = qkv[..., hq * hd: (hq + hkv) * hd].reshape(*lead, hkv, hd)
    v = qkv[..., (hq + hkv) * hd:].reshape(*lead, hkv, hd)
    return q, k, v


def _head_spec(parallel: Parallel, n_heads: int, *, seq_fallback: bool):
    """Attention-activation sharding that never splits head_dim.

    §Perf iteration 1: the naive fused-feature constraint lets GSPMD split
    *inside* head_dim whenever heads don't divide TP; the attention-score
    contraction then runs over a sharded dim and every score tile is
    all-reduced (the dominant collective in the baseline profile).

    §Perf iteration 6: when heads ∤ TP, replicating attention 16× blows up
    the compute/memory terms at 32k prefill — instead shard the *query
    sequence* dim (context parallelism): scores shard over q-rows with no
    partial-sum contraction, k/v stay replicated.  Measured crossover: the
    backward-pass reshard of token-sharded activations makes this a small
    loss at T=4k training but a 60–69% collective win at 32k prefill, so it
    engages at T ≥ 8192."""
    tp = parallel.tp_size
    if tp > 1 and n_heads % tp == 0:
        return parallel.batch_spec(None, parallel.model_axis, None)
    if seq_fallback and tp > 1:
        return parallel.batch_spec(parallel.model_axis, None, None)
    return parallel.batch_spec(None, None, None)


_SEQ_FALLBACK_MIN_T = 8192


def attn_apply(spec: AttnSpec, params: Params, x: jax.Array,
               positions: jax.Array, parallel: Parallel = NO_PARALLEL,
               *, memory: jax.Array | None = None) -> jax.Array:
    """Full-sequence attention (training / prefill).  x: (B, T, d)."""
    cfg = spec.cfg
    hq, hkv, hd = spec.dims
    B, T, _ = x.shape
    qkv = linear_apply(spec.qkv, params["qkv"], x)  # (B, T, (hq+2hkv)·hd)
    q, k, v = _split_qkv(spec, qkv)
    long_seq = T >= _SEQ_FALLBACK_MIN_T
    q = parallel.constraint(q, _head_spec(parallel, hq, seq_fallback=long_seq))
    k = parallel.constraint(k, _head_spec(parallel, hkv, seq_fallback=False))
    v = parallel.constraint(v, _head_spec(parallel, hkv, seq_fallback=False))
    if spec.cross:
        assert memory is not None
        mkv = linear_apply(spec.qkv, params["qkv"], memory)
        _, k, v = _split_qkv(spec, mkv)
        causal = False
    else:
        causal = spec.causal
        if cfg.pos_embed == "rope":
            q = ops.rope(q, positions, cfg.rope_theta)
            k = ops.rope(k, positions, cfg.rope_theta)
    o = ops.chunked_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, window=spec.window,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, hq * hd)
    y = linear_apply(spec.out, params["out"], o)
    return parallel.shard_batch(y)


def attn_cache_init(spec: AttnSpec, batch: int, max_len: int, dtype) -> Params:
    """KV cache.  Sliding-window layers allocate a ring buffer of the window
    size (this is what makes long_500k decode O(window) not O(T)).  ``pos``
    is per-slot-per-row so continuous batching can mix sequence lengths.

    With ``cfg.cache_quant`` (the ``quant.cache`` knob or legacy
    ``kv_quant``) the K/V tensors are int8 with per-(slot, head) bf16 scales
    — halves the dominant decode-memory term (beyond-paper; §Perf
    iteration 3).  The same row-wise codec (repro/quant) backs the MLA
    latent and SSD/RG-LRU state caches."""
    hq, hkv, hd = spec.dims
    S = min(max_len, spec.window) if spec.window else max_len
    c: Params = {
        "pos": jnp.full((batch, S), -1, dtype=jnp.int32),
    }
    if spec.cfg.cache_quant:
        c["k"] = jnp.zeros((batch, S, hkv, hd), jnp.int8)
        c["v"] = jnp.zeros((batch, S, hkv, hd), jnp.int8)
        c["k_scale"] = jnp.zeros((batch, S, hkv), jnp.bfloat16)
        c["v_scale"] = jnp.zeros((batch, S, hkv), jnp.bfloat16)
    else:
        c["k"] = jnp.zeros((batch, S, hkv, hd), dtype=dtype)
        c["v"] = jnp.zeros((batch, S, hkv, hd), dtype=dtype)
    return c


def attn_cache_axes(spec: AttnSpec) -> Axes:
    # §Perf iteration 2: shard the cache on the SEQUENCE dim over the model
    # axis — always divisible (unlike kv_heads), so a 32k-deep cache never
    # replicates 16×.  Decode attention contracts s (sharded) → the partial
    # sum is one tiny (B,H,D) all-reduce per layer instead of a 16×-bigger
    # resident cache.
    a: Axes = {"k": ("batch", "kv_seq", "kv_heads", None),
               "v": ("batch", "kv_seq", "kv_heads", None),
               "pos": ("batch", "kv_seq")}
    # cross-attention memory caches stay float (cross_memory_cache) — only
    # self-attention caches carry int8 + scales under cache_quant
    if spec.cfg.cache_quant and not spec.cross:
        a["k_scale"] = ("batch", "kv_seq", "kv_heads")
        a["v_scale"] = ("batch", "kv_seq", "kv_heads")
    return a


def _step_vec(step: jax.Array, batch: int) -> jax.Array:
    step = jnp.asarray(step, jnp.int32)
    return jnp.broadcast_to(step, (batch,)) if step.ndim == 0 else step


def attn_prefill(spec: AttnSpec, params: Params, cache: Params, x: jax.Array,
                 steps: jax.Array, n_tokens: jax.Array,
                 parallel: Parallel = NO_PARALLEL,
                 *, memory: jax.Array | None = None) -> tuple[jax.Array, Params]:
    """Multi-token prefill at per-slot offsets (chunked-prefill step).

    x: (B, C, d); steps: (B,) absolute position of each row's first token;
    n_tokens: (B,) live tokens per row.  Rows are ragged: column i of row b
    is live iff ``i < n_tokens[b]``; dead columns are dropped from the cache
    write (OOB-scatter with mode="drop") and produce garbage outputs the
    engine discards.  C=1 with n_tokens=1 is exactly single-token decode.
    """
    cfg = spec.cfg
    hq, hkv, hd = spec.dims
    B, C, _ = x.shape
    offs = jnp.arange(C, dtype=jnp.int32)
    q_pos = steps[:, None] + offs[None, :]           # (B, C)
    valid = offs[None, :] < n_tokens[:, None]        # (B, C)
    qkv = linear_apply(spec.qkv, params["qkv"], x)
    q, k, v = _split_qkv(spec, qkv)
    if spec.cross:
        # Cross-attention reads the (precomputed) encoder memory cache as-is.
        o = ops.cache_attention(
            q.transpose(0, 2, 1, 3), cache["k"], cache["v"], cache["pos"],
            jnp.full((B, C), jnp.iinfo(jnp.int32).max // 2, jnp.int32))
        y = linear_apply(spec.out, params["out"],
                         o.transpose(0, 2, 1, 3).reshape(B, C, hq * hd))
        return parallel.shard_batch(y), cache
    if cfg.pos_embed == "rope":
        q = ops.rope(q, q_pos, cfg.rope_theta)
        k = ops.rope(k, q_pos, cfg.rope_theta)
    S = cache["k"].shape[1]
    rows = jnp.arange(B)[:, None]
    # Ring-buffer write: when the chunk is longer than the ring (C > S only
    # happens for sliding-window layers), only a token whose slot is not
    # re-written later in the same chunk survives — i + S >= n_tokens[b].
    survives = valid & (offs[None, :] + S >= n_tokens[:, None])
    slot = jnp.where(survives, q_pos % S, S)         # S = OOB → dropped
    new_cache = dict(cache)
    k_pos = cache["pos"].at[rows, slot].set(q_pos, mode="drop")
    new_cache["pos"] = k_pos
    if spec.cfg.cache_quant:
        kq, ks = qt.quantize_rows(k)
        vq, vs = qt.quantize_rows(v)
        new_cache["k"] = cache["k"].at[rows, slot].set(kq, mode="drop")
        new_cache["v"] = cache["v"].at[rows, slot].set(vq, mode="drop")
        new_cache["k_scale"] = cache["k_scale"].at[rows, slot].set(ks, mode="drop")
        new_cache["v_scale"] = cache["v_scale"].at[rows, slot].set(vs, mode="drop")
        k_cache = qt.dequantize_rows(new_cache["k"], new_cache["k_scale"], x.dtype)
        v_cache = qt.dequantize_rows(new_cache["v"], new_cache["v_scale"], x.dtype)
    else:
        k_cache = cache["k"].at[rows, slot].set(k, mode="drop")
        v_cache = cache["v"].at[rows, slot].set(v, mode="drop")
        new_cache["k"], new_cache["v"] = k_cache, v_cache
    if spec.window is not None and C > 1:
        # Ring hazard: within one chunk a later token may overwrite a slot
        # still inside an earlier query's window.  Attend over the pre-write
        # ring ‖ the chunk itself — the position mask picks the right keys.
        kv_pos = jnp.where(valid, q_pos, -1)
        if spec.cfg.cache_quant:
            k_old = qt.dequantize_rows(cache["k"], cache["k_scale"], x.dtype)
            v_old = qt.dequantize_rows(cache["v"], cache["v_scale"], x.dtype)
            # attend to the chunk's own keys through the same int8
            # round-trip the C=1 path reads back from the cache
            k = qt.dequantize_rows(kq, ks, x.dtype)
            v = qt.dequantize_rows(vq, vs, x.dtype)
        else:
            k_old, v_old = cache["k"], cache["v"]
        o = ops.cache_attention(
            q.transpose(0, 2, 1, 3),
            jnp.concatenate([k_old, k.astype(k_old.dtype)], axis=1),
            jnp.concatenate([v_old, v.astype(v_old.dtype)], axis=1),
            jnp.concatenate([cache["pos"], kv_pos], axis=1),
            q_pos, window=spec.window)
    else:
        o = ops.cache_attention(q.transpose(0, 2, 1, 3), k_cache, v_cache,
                                k_pos, q_pos, window=spec.window)
    # o is (B, Hq, C, hd) — token-major flatten needs the transpose (a
    # straight reshape is only layout-neutral at C=1)
    y = linear_apply(spec.out, params["out"],
                     o.transpose(0, 2, 1, 3).reshape(B, C, hq * hd))
    return parallel.shard_batch(y), new_cache


def attn_decode(spec: AttnSpec, params: Params, cache: Params, x: jax.Array,
                step: jax.Array, parallel: Parallel = NO_PARALLEL,
                *, memory: jax.Array | None = None) -> tuple[jax.Array, Params]:
    """Single-token decode.  x: (B, 1, d); step: scalar or (B,) positions."""
    B = x.shape[0]
    return attn_prefill(spec, params, cache, x, _step_vec(step, B),
                        jnp.ones((B,), jnp.int32), parallel, memory=memory)


def cross_memory_cache(spec: AttnSpec, params: Params, memory: jax.Array) -> Params:
    """Precompute the decoder cross-attention K/V from encoder output."""
    mkv = linear_apply(spec.qkv, params["qkv"], memory)
    _, k, v = _split_qkv(spec, mkv)
    B, S = memory.shape[0], memory.shape[1]
    return {"k": k, "v": v,
            "pos": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))}


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V3 §: multi-head latent attention).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLASpec:
    cfg: ArchConfig
    mla: MLACfg
    wq_a: LinearSpec   # d_model -> q_lora
    wq_b: LinearSpec   # q_lora -> H·(nope+rope)
    wkv_a: LinearSpec  # d_model -> kv_lora + rope  (latent + shared k_rope)
    wkv_b: LinearSpec  # kv_lora -> H·(nope+v)
    out: LinearSpec    # H·v -> d_model


def make_mla(cfg: ArchConfig) -> MLASpec:
    m = cfg.mla
    H = cfg.n_heads
    st = cfg.structure
    return MLASpec(
        cfg=cfg, mla=m,
        wq_a=make_linear(cfg.d_model, m.q_lora_rank, st),
        wq_b=make_linear(m.q_lora_rank, H * (m.nope_head_dim + m.rope_head_dim), st),
        wkv_a=make_linear(cfg.d_model, m.kv_lora_rank + m.rope_head_dim, st),
        wkv_b=make_linear(m.kv_lora_rank, H * (m.nope_head_dim + m.v_head_dim), st),
        out=make_linear(H * m.v_head_dim, cfg.d_model, st),
    )


def mla_init(spec: MLASpec, key, dtype) -> Params:
    ks = jax.random.split(key, 5)
    return {
        "wq_a": linear_init(spec.wq_a, ks[0], dtype),
        "q_norm": norm_init(spec.mla.q_lora_rank, "rmsnorm", dtype),
        "wq_b": linear_init(spec.wq_b, ks[1], dtype),
        "wkv_a": linear_init(spec.wkv_a, ks[2], dtype),
        "kv_norm": norm_init(spec.mla.kv_lora_rank, "rmsnorm", dtype),
        "wkv_b": linear_init(spec.wkv_b, ks[3], dtype),
        "out": linear_init(spec.out, ks[4], dtype,
                           scale=1.0 / math.sqrt(2 * spec.cfg.n_layers * spec.out.d_in)),
    }


def mla_axes(spec: MLASpec) -> Axes:
    return {
        "wq_a": linear_axes(spec.wq_a, out_axis=None),
        "q_norm": norm_axes("rmsnorm"),
        "wq_b": linear_axes(spec.wq_b, in_axis=None, out_axis="heads"),
        "wkv_a": linear_axes(spec.wkv_a, out_axis=None),
        "kv_norm": norm_axes("rmsnorm"),
        "wkv_b": linear_axes(spec.wkv_b, in_axis=None, out_axis="heads"),
        "out": linear_axes(spec.out, in_axis="heads", out_axis="fsdp_in"),
    }


def mla_quantize(spec: MLASpec, params: Params, bits: int = 8) -> Params:
    qp = dict(params)  # norms pass through
    for name in ("wq_a", "wq_b", "wkv_a", "wkv_b", "out"):
        qp[name] = linear_quantize(getattr(spec, name), params[name], bits)
    return qp


def mla_prestack(spec: MLASpec, params: Params) -> Params:
    """Pre-stack the MLA a-projection bundle (wq_a + wkv_a) once at load."""
    b = linear_group_prestack((spec.wq_a, spec.wkv_a),
                              (params["wq_a"], params["wkv_a"]))
    return {**params, "_bundle_a": b} if b is not None else params


def kv_cache_rollback(old: Params, new: Params, steps: jax.Array,
                      n_comm: jax.Array) -> Params:
    """Rewind a KV cache (attn ring buffer or MLA latent) to the first
    ``n_comm`` tokens of a chunk written at ``steps``.

    Every leaf carries a position row ``pos (B, S)`` (−1 = empty): entries
    whose position exceeds the last committed one revert to the pre-chunk
    cache.  Reverting from ``old`` (not just clearing) matters for the
    sliding-window ring: a rejected draft's write may have *overwritten* a
    still-live slot (q_pos % S collision), and only the old leaf has the
    original entry.  The result is bit-identical to having written
    ``n_comm`` tokens in the first place."""
    commit_last = (steps + n_comm - 1)[:, None]          # (B, 1)
    revert = new["pos"] > commit_last                    # (B, S)
    out = {}
    for k, v in new.items():
        m = revert.reshape(revert.shape + (1,) * (v.ndim - revert.ndim))
        out[k] = jnp.where(m, old[k], v)
    return out


def _mla_qkv(spec: MLASpec, params: Params, x: jax.Array, positions: jax.Array):
    """Shared q path + latent path.  Returns q_nope, q_rope, latent, k_rope.

    The two a-projections both consume ``x`` and are shape-congruent up to
    zero padding (same d_in, same block count), so they run as one grouped
    launch — a layer-level decode launch saved on every MLA step."""
    m = spec.mla
    H = spec.cfg.n_heads
    *lead, _ = x.shape
    q_lat, kv = linear_group_apply(
        (spec.wq_a, spec.wkv_a), (params["wq_a"], params["wkv_a"]), x,
        bundle=params.get("_bundle_a"))
    q_lat = norm_apply(params["q_norm"], q_lat, "rmsnorm")
    q = linear_apply(spec.wq_b, params["wq_b"], q_lat)
    q = q.reshape(*lead, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim:]
    latent, k_rope = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    latent = norm_apply(params["kv_norm"], latent, "rmsnorm")
    q_rope = ops.rope(q_rope, positions, spec.cfg.rope_theta)
    k_rope = ops.rope(k_rope[..., None, :], positions, spec.cfg.rope_theta)[..., 0, :]
    return q_nope, q_rope, latent, k_rope


def mla_apply(spec: MLASpec, params: Params, x: jax.Array, positions: jax.Array,
              parallel: Parallel = NO_PARALLEL) -> jax.Array:
    """Training / prefill MLA: expand latent to per-head K/V, chunked attn."""
    m = spec.mla
    H = spec.cfg.n_heads
    B, T, _ = x.shape
    q_nope, q_rope, latent, k_rope = _mla_qkv(spec, params, x, positions)
    kv = linear_apply(spec.wkv_b, params["wkv_b"], latent)
    kv = kv.reshape(B, T, H, m.nope_head_dim + m.v_head_dim)
    kv = parallel.constraint(kv, _head_spec(parallel, H, seq_fallback=False))
    q_nope = parallel.constraint(
        q_nope, _head_spec(parallel, H, seq_fallback=True))
    k_nope, v = kv[..., : m.nope_head_dim], kv[..., m.nope_head_dim:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None, :], (B, T, H, m.rope_head_dim))], axis=-1)
    o = ops.chunked_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, q_chunk=spec.cfg.q_chunk, kv_chunk=spec.cfg.kv_chunk)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, H * m.v_head_dim)
    y = linear_apply(spec.out, params["out"], o)
    return parallel.shard_batch(y)


def mla_cache_init(spec: MLASpec, batch: int, max_len: int, dtype) -> Params:
    """Latent cache; with ``cfg.cache_quant`` the per-token latent and
    shared-rope vectors are int8 with per-(slot, token) bf16 scales — MLA's
    cache is already compressed (kv_lora ≪ H·hd), int8 halves it again."""
    m = spec.mla
    c: Params = {"pos": jnp.full((batch, max_len), -1, dtype=jnp.int32)}
    if spec.cfg.cache_quant:
        c["latent"] = jnp.zeros((batch, max_len, m.kv_lora_rank), jnp.int8)
        c["k_rope"] = jnp.zeros((batch, max_len, m.rope_head_dim), jnp.int8)
        c["latent_scale"] = jnp.zeros((batch, max_len), jnp.bfloat16)
        c["k_rope_scale"] = jnp.zeros((batch, max_len), jnp.bfloat16)
    else:
        c["latent"] = jnp.zeros((batch, max_len, m.kv_lora_rank), dtype=dtype)
        c["k_rope"] = jnp.zeros((batch, max_len, m.rope_head_dim), dtype=dtype)
    return c


def mla_cache_axes(spec: MLASpec) -> Axes:
    a: Axes = {"latent": ("batch", "kv_seq", None),
               "k_rope": ("batch", "kv_seq", None),
               "pos": ("batch", "kv_seq")}
    if spec.cfg.cache_quant:
        a["latent_scale"] = ("batch", "kv_seq")
        a["k_rope_scale"] = ("batch", "kv_seq")
    return a


def mla_prefill(spec: MLASpec, params: Params, cache: Params, x: jax.Array,
                steps: jax.Array, n_tokens: jax.Array,
                parallel: Parallel = NO_PARALLEL) -> tuple[jax.Array, Params]:
    """Latent-cache prefill/decode with absorbed up-projections.

    The cache holds only (kv_lora + rope) per token — the whole point of MLA.
    W_uk / W_uv are materialized from the (possibly structured) wkv_b and
    absorbed into the score / output einsums:
        score_h(t) = q_nope_h · W_uk_h · c_t  +  q_rope_h · k_rope_t
        out_h      = (Σ_t p_t · c_t) · W_uv_h
    x: (B, C, d); steps/n_tokens: (B,) per-slot offsets and live counts
    (ragged rows, see ``attn_prefill``).  C=1 is classic decode.
    """
    m = spec.mla
    H = spec.cfg.n_heads
    B, C, _ = x.shape
    offs = jnp.arange(C, dtype=jnp.int32)
    q_pos = steps[:, None] + offs[None, :]           # (B, C)
    valid = offs[None, :] < n_tokens[:, None]
    q_nope, q_rope, latent, k_rope = _mla_qkv(spec, params, x, q_pos)
    rows = jnp.arange(B)[:, None]
    S = cache["latent"].shape[1]
    slot = jnp.where(valid, q_pos, S)                # MLA cache is not a ring
    new_cache: Params = {}
    if spec.cfg.cache_quant:
        lq, ls = qt.quantize_rows(latent)
        rq, rs = qt.quantize_rows(k_rope)
        new_cache["latent"] = cache["latent"].at[rows, slot].set(lq, mode="drop")
        new_cache["k_rope"] = cache["k_rope"].at[rows, slot].set(rq, mode="drop")
        new_cache["latent_scale"] = cache["latent_scale"].at[rows, slot].set(
            ls, mode="drop")
        new_cache["k_rope_scale"] = cache["k_rope_scale"].at[rows, slot].set(
            rs, mode="drop")
        lat_cache = qt.dequantize_rows(new_cache["latent"],
                                       new_cache["latent_scale"], x.dtype)
        rope_cache = qt.dequantize_rows(new_cache["k_rope"],
                                        new_cache["k_rope_scale"], x.dtype)
    else:
        lat_cache = cache["latent"].at[rows, slot].set(latent, mode="drop")
        rope_cache = cache["k_rope"].at[rows, slot].set(k_rope, mode="drop")
        new_cache["latent"], new_cache["k_rope"] = lat_cache, rope_cache
    k_pos = cache["pos"].at[rows, slot].set(q_pos, mode="drop")
    new_cache["pos"] = k_pos

    w = linear_dense_matrix(spec.wkv_b, params["wkv_b"])  # (kv_lora, H·(nope+v))
    w = w.reshape(m.kv_lora_rank, H, m.nope_head_dim + m.v_head_dim)
    w_uk, w_uv = w[..., : m.nope_head_dim], w[..., m.nope_head_dim:]

    q_lat = jnp.einsum("bthn,chn->bthc", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))  # (B,C,H,kv_lora)
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    s = (jnp.einsum("bthc,bsc->bhts", q_lat, lat_cache.astype(jnp.float32))
         + jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32),
                      rope_cache.astype(jnp.float32))) * scale
    ok = (k_pos[:, None, :] >= 0) & (k_pos[:, None, :] <= q_pos[:, :, None])
    s = jnp.where(ok[:, None, :, :], s, ops.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # idle slots see an empty cache
    o_lat = jnp.einsum("bhts,bsc->bthc", p, lat_cache.astype(jnp.float32))
    o = jnp.einsum("bthc,hcv->bthv", o_lat,
                   w_uv.transpose(1, 0, 2).astype(jnp.float32))
    o = o.reshape(B, C, H * m.v_head_dim).astype(x.dtype)
    y = linear_apply(spec.out, params["out"], o)
    return parallel.shard_batch(y), new_cache


def mla_decode(spec: MLASpec, params: Params, cache: Params, x: jax.Array,
               step: jax.Array, parallel: Parallel = NO_PARALLEL
               ) -> tuple[jax.Array, Params]:
    """Single-token MLA decode — ``mla_prefill`` with C=1."""
    B = x.shape[0]
    return mla_prefill(spec, params, cache, x, _step_vec(step, B),
                       jnp.ones((B,), jnp.int32), parallel)


# ---------------------------------------------------------------------------
# Feed-forward (SwiGLU / GELU), structured.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FFNSpec:
    """SwiGLU FFNs model gate and up as two congruent (d → ff) structured
    linears sharing the input — the canonical grouped-projection bundle:
    they dispatch as ONE grouped matmul launch (``linear_group_apply``)
    with one x-tile load, same total parameter budget as the previously
    fused d → 2·ff matrix.  GELU FFNs keep the single ``wi``."""

    kind: str  # swiglu | gelu
    wo: LinearSpec                 # ff -> d
    wi: LinearSpec | None = None   # gelu: d -> ff
    gate: LinearSpec | None = None  # swiglu: d -> ff
    up: LinearSpec | None = None    # swiglu: d -> ff

    @property
    def in_specs(self) -> tuple[LinearSpec, ...]:
        """The input-side projection bundle (all consume the block input)."""
        return (self.gate, self.up) if self.kind == "swiglu" else (self.wi,)


def make_ffn(d_model: int, d_ff: int, kind: str,
             structure: StructureConfig) -> FFNSpec:
    wo = make_linear(d_ff, d_model, structure)
    if kind == "swiglu":
        return FFNSpec(kind=kind, wo=wo,
                       gate=make_linear(d_model, d_ff, structure),
                       up=make_linear(d_model, d_ff, structure))
    return FFNSpec(kind=kind, wo=wo, wi=make_linear(d_model, d_ff, structure))


def ffn_init(spec: FFNSpec, key, dtype, n_layers: int = 1) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    wo_scale = 1.0 / math.sqrt(2 * n_layers * spec.wo.d_in)
    if spec.kind == "swiglu":
        return {"gate": linear_init(spec.gate, k1, dtype),
                "up": linear_init(spec.up, k3, dtype),
                "wo": linear_init(spec.wo, k2, dtype, scale=wo_scale)}
    return {"wi": linear_init(spec.wi, k1, dtype),
            "wo": linear_init(spec.wo, k2, dtype, scale=wo_scale)}


def ffn_axes(spec: FFNSpec) -> Axes:
    a: Axes = {"wo": linear_axes(spec.wo, in_axis="ffn", out_axis="fsdp_in")}
    if spec.kind == "swiglu":
        a["gate"] = linear_axes(spec.gate, out_axis="ffn")
        a["up"] = linear_axes(spec.up, out_axis="ffn")
    else:
        a["wi"] = linear_axes(spec.wi, out_axis="ffn")
    return a


def ffn_quantize(spec: FFNSpec, params: Params, bits: int = 8) -> Params:
    if spec.kind == "swiglu":
        return {"gate": linear_quantize(spec.gate, params["gate"], bits),
                "up": linear_quantize(spec.up, params["up"], bits),
                "wo": linear_quantize(spec.wo, params["wo"], bits)}
    return {"wi": linear_quantize(spec.wi, params["wi"], bits),
            "wo": linear_quantize(spec.wo, params["wo"], bits)}


def ffn_prestack(spec: FFNSpec, params: Params) -> Params:
    """Pre-stack the SwiGLU gate+up bundle once at load (GELU: no bundle)."""
    if spec.kind != "swiglu":
        return params
    b = linear_group_prestack((spec.gate, spec.up),
                              (params["gate"], params["up"]))
    return {**params, "_bundle_in": b} if b is not None else params


def ffn_apply(spec: FFNSpec, params: Params, x: jax.Array,
              parallel: Parallel = NO_PARALLEL) -> jax.Array:
    if spec.kind == "swiglu":
        gate, up = linear_group_apply(
            (spec.gate, spec.up), (params["gate"], params["up"]), x,
            bundle=params.get("_bundle_in"))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(linear_apply(spec.wi, params["wi"], x))
    h = parallel.constraint(h, parallel.batch_spec(None, parallel.model_axis))
    y = linear_apply(spec.wo, params["wo"], h)
    return parallel.shard_batch(y)
