"""Mixture-of-Experts layer: top-k routing, capacity-based dispatch, and
expert parallelism over the mesh "model" axis via ``shard_map`` + all_to_all.

Design (DeepSeek-/GShard-style, TPU-native):

  * The router (kept dense — small and accuracy-critical) picks top-k experts
    per token; gates are renormalized over the chosen k.
  * Dispatch is *per device*: each device routes its own Tl tokens into an
    ``(E, C, d)`` buffer with local capacity ``C = ceil(Tl·k·cf / E)``.
    Position-in-expert is computed with an argsort (O(Tl·k·log) — no
    (Tl·k × E) one-hot cumsum), and the buffer is built by *gather*
    (slot → token index), never materializing the (Tl·k, d) replica.
  * Expert parallelism: ``all_to_all`` over the model axis sends each
    expert-shard's slice to the owning device; experts run as one batched
    (vmapped) structured matmul — BLAST expert weights batch over E exactly
    like dense ones; a second ``all_to_all`` returns the outputs.
  * Combine is a local gather + gate-weighted sum.  Dropped tokens (beyond
    capacity) contribute zero, standard for capacity-based MoE.

With ``parallel.mesh is None`` the identical dispatch math runs on one
device (ep = 1, no collectives) — this is the smoke-test path and also the
oracle for the shard_map path (tested in tests/test_moe.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoECfg
from repro.core.structures import LinearSpec, make_linear
from repro.models import layers as L
from repro.parallel import Parallel, NO_PARALLEL

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    cfg: ArchConfig
    moe: MoECfg
    router: LinearSpec           # d -> E (dense)
    wi: LinearSpec               # per-expert d -> 2·d_expert (swiglu fused)
    wo: LinearSpec               # per-expert d_expert -> d
    shared: L.FFNSpec | None     # DeepSeek shared expert(s)


def make_moe(cfg: ArchConfig) -> MoESpec:
    m = cfg.moe
    st = cfg.ffn_structure
    shared = None
    if m.n_shared:
        shared = L.make_ffn(cfg.d_model, m.n_shared * m.d_shared, cfg.ffn_kind, st)
    return MoESpec(
        cfg=cfg, moe=m,
        router=make_linear(cfg.d_model, m.n_experts, structured=False),
        wi=make_linear(cfg.d_model, 2 * m.d_expert, st),
        wo=make_linear(m.d_expert, cfg.d_model, st),
        shared=shared,
    )


def moe_init(spec: MoESpec, key, dtype) -> Params:
    m = spec.moe
    kr, ki, ko, ks = jax.random.split(key, 4)
    init_wi = lambda k: L.linear_init(spec.wi, k, dtype)
    init_wo = lambda k: L.linear_init(
        spec.wo, k, dtype, scale=1.0 / math.sqrt(2 * spec.cfg.n_layers * spec.wo.d_in))
    p: Params = {
        "router": L.linear_init(spec.router, kr, jnp.float32),
        "wi": jax.vmap(init_wi)(jax.random.split(ki, m.n_experts)),
        "wo": jax.vmap(init_wo)(jax.random.split(ko, m.n_experts)),
    }
    if spec.shared is not None:
        p["shared"] = L.ffn_init(spec.shared, ks, dtype, spec.cfg.n_layers)
    return p


def moe_axes(spec: MoESpec) -> dict:
    expert = lambda ax: {k: ("experts",) + v for k, v in ax.items()}
    a = {
        "router": L.linear_axes(spec.router, in_axis=None, out_axis=None),
        "wi": expert(L.linear_axes(spec.wi, in_axis="fsdp_in", out_axis="expert_ffn")),
        "wo": expert(L.linear_axes(spec.wo, in_axis="expert_ffn", out_axis="fsdp_in")),
    }
    if spec.shared is not None:
        a["shared"] = L.ffn_axes(spec.shared)
    return a


def moe_quantize(spec: MoESpec, params: Params, bits: int = 8) -> Params:
    """Quantize the expert linears (vmapped over the stacked E axis — the
    QArray pytree stacks like any params tree).  The router stays float:
    it is tiny and routing decisions are precision-sensitive."""
    qp = dict(params)
    qp["wi"] = jax.vmap(lambda p: L.linear_quantize(spec.wi, p, bits))(
        params["wi"])
    qp["wo"] = jax.vmap(lambda p: L.linear_quantize(spec.wo, p, bits))(
        params["wo"])
    if spec.shared is not None:
        qp["shared"] = L.ffn_quantize(spec.shared, params["shared"], bits)
    return qp


def moe_prestack(spec: MoESpec, params: Params) -> Params:
    """Pre-stack the shared expert's gate+up bundle (the routed experts
    dispatch per-expert through ``linear_apply`` — no bundle there)."""
    if spec.shared is None:
        return params
    return {**params,
            "shared": L.ffn_prestack(spec.shared, params["shared"])}


# -- dispatch math (runs per device; identical with or without shard_map) ----


def _route(spec: MoESpec, router_p: Params, x2d: jax.Array):
    """x2d: (Tl, d) → gates (Tl, k), expert ids (Tl, k), aux loss (scalar)."""
    m = spec.moe
    logits = L.linear_apply(spec.router, router_p, x2d.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (Tl, E)
    gates, eidx = jax.lax.top_k(probs, m.top_k)                  # (Tl, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E · Σ_e f_e · P_e
    f = jnp.zeros((m.n_experts,), jnp.float32).at[eidx.reshape(-1)].add(1.0)
    f = f / (x2d.shape[0] * m.top_k)
    pbar = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(f * pbar)
    return gates.astype(x2d.dtype), eidx, aux


def _positions_in_expert(eidx_flat: jax.Array, n_experts: int) -> jax.Array:
    """Rank of each assignment within its expert, via argsort (no E-wide
    one-hot cumsum).  eidx_flat: (N,) → pos: (N,)."""
    N = eidx_flat.shape[0]
    order = jnp.argsort(eidx_flat, stable=True)                  # group by expert
    sorted_e = eidx_flat[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    rank_sorted = jnp.arange(N) - seg_start[sorted_e]
    return jnp.zeros((N,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))


def _dispatch_indices(eidx: jax.Array, n_experts: int, capacity: int):
    """→ slot_token (E, C) source row in the (Tl·k) assignment list (-1 empty),
       pos (Tl, k) position-in-expert, keep (Tl, k) within-capacity mask."""
    Tl, k = eidx.shape
    flat = eidx.reshape(-1)
    pos = _positions_in_expert(flat, n_experts)
    keep = pos < capacity
    # mode="drop": assignments with pos >= capacity are silently dropped —
    # no clamped write can clobber a live slot.
    slot_token = jnp.full((n_experts, capacity), -1, jnp.int32)
    slot_token = slot_token.at[flat, pos].set(
        jnp.arange(Tl * k, dtype=jnp.int32), mode="drop")
    return slot_token, pos.reshape(Tl, k), keep.reshape(Tl, k)


def _expert_ffn(spec: MoESpec, params: Params, xe: jax.Array) -> jax.Array:
    """xe: (E_loc, N, d) → (E_loc, N, d); one batched structured matmul."""
    def one(wi, wo, x):
        h = L.linear_apply(spec.wi, wi, x)
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
        return L.linear_apply(spec.wo, wo, h)
    return jax.vmap(one)(params["wi"], params["wo"], xe)


def _moe_body(spec: MoESpec, params: Params, x: jax.Array,
              ep_axis: str | None, ep_size: int):
    """Per-device MoE.  x: (B_loc, T, d) → (y, aux)."""
    m = spec.moe
    B, T, d = x.shape
    Tl = B * T
    x2d = x.reshape(Tl, d)
    gates, eidx, aux = _route(spec, params["router"], x2d)
    capacity = max(1, int(math.ceil(Tl * m.top_k * m.capacity_factor / m.n_experts)))
    slot_token, pos, keep = _dispatch_indices(eidx, m.n_experts, capacity)

    # ---- gather tokens into the dispatch buffer (E, C, d)
    valid = slot_token >= 0
    src_row = jnp.where(valid, slot_token, 0) // m.top_k
    xe = x2d[src_row] * valid[..., None].astype(x2d.dtype)       # (E, C, d)

    if ep_axis is not None and ep_size > 1:
        e_loc = m.n_experts // ep_size
        # (E, C, d) → (ep, e_loc, C, d): chunk p holds the slice destined for
        # the device owning experts [p·e_loc, (p+1)·e_loc).
        xe = xe.reshape(ep_size, e_loc, capacity, d)
        xe = jax.lax.all_to_all(xe, ep_axis, split_axis=0, concat_axis=0, tiled=True)
        # now axis 0 indexes the SOURCE peer → batch per local expert
        xe = xe.transpose(1, 0, 2, 3).reshape(e_loc, ep_size * capacity, d)
        ye = _expert_ffn(spec, params, xe)                       # local experts
        ye = ye.reshape(e_loc, ep_size, capacity, d).transpose(1, 0, 2, 3)
        ye = jax.lax.all_to_all(ye, ep_axis, split_axis=0, concat_axis=0, tiled=True)
        ye = ye.reshape(m.n_experts, capacity, d)
    else:
        ye = _expert_ffn(spec, params, xe)                       # (E, C, d)

    # ---- combine: y_t = Σ_k gate · ye[e_k, pos_k]
    safe_pos = jnp.minimum(pos, capacity - 1)
    yk = ye[eidx, safe_pos]                                      # (Tl, k, d)
    w = (gates * keep.astype(gates.dtype))[..., None]
    y = jnp.sum(yk * w, axis=1).reshape(B, T, d)
    return y, aux


def moe_apply(spec: MoESpec, params: Params, x: jax.Array,
              parallel: Parallel = NO_PARALLEL) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, d) → (y, aux_loss).  Shared experts (if any) added in."""
    m = spec.moe
    use_ep = (parallel.active and parallel.model_axis is not None
              and parallel.mesh.shape[parallel.model_axis] > 1
              and m.n_experts % parallel.mesh.shape[parallel.model_axis] == 0)
    if use_ep:
        mesh = parallel.mesh
        ep_axis = parallel.model_axis
        ep_size = mesh.shape[ep_axis]
        dp = parallel.data_axes or None
        all_axes = tuple(mesh.axis_names)

        def body(px, prouter, pwi, pwo):
            pp = {"router": prouter, "wi": pwi, "wo": pwo}
            # dispatch runs against the *global* expert count with local
            # capacity; params wi/wo enter as local E/ep shards.
            y, aux = _moe_body(spec, pp, px, ep_axis, ep_size)
            return y, jax.lax.pmean(aux, all_axes)

        y, aux = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(dp, None, None),
                      jax.tree.map(lambda _: P(), params["router"]),
                      jax.tree.map(lambda _: P(ep_axis), params["wi"]),
                      jax.tree.map(lambda _: P(ep_axis), params["wo"])),
            out_specs=(P(dp, None, None), P()),
            check_vma=False,
        )(x, params["router"], params["wi"], params["wo"])
    else:
        y, aux = _moe_body(spec, params, x, None, 1)
    if spec.shared is not None:
        y = y + L.ffn_apply(spec.shared, params["shared"], x, parallel)
    return parallel.shard_batch(y), aux
