"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Residual-block layout (Griffin §2.4): two parallel branches from the input —
a GeLU gate branch and a (causal depthwise conv → RG-LRU) branch — merged by
elementwise product and projected back to d_model.  The in/out projections
are *structured linears* (BLAST-able); the RG-LRU gates are block-diagonal
(one block per head, as in the reference implementation) and the per-channel
decay Λ is a vector.

RG-LRU recurrence (fp32, associative-scan over T):

    r_t = σ(W_a x_t + b_a)          recurrence gate
    i_t = σ(W_x x_t + b_x)          input gate
    a_t = exp(-c · softplus(Λ) · r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Decode carries (conv buffer, h) — O(1) per token, which is what makes the
``long_500k`` cell representable for this family.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import quant as qt
from repro.configs.base import ArchConfig
from repro.core.structures import LinearSpec, StructureConfig, make_linear
from repro.models import layers as L
from repro.parallel import Parallel, NO_PARALLEL

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class RGLRUSpec:
    cfg: ArchConfig
    width: int
    conv_width: int
    c: float
    in_x: LinearSpec      # d_model -> width   (recurrence branch)
    in_gate: LinearSpec   # d_model -> width   (GeLU gate branch)
    out: LinearSpec       # width -> d_model
    gate_a: LinearSpec    # width -> width, block-diagonal (per head)
    gate_x: LinearSpec


def make_rglru(cfg: ArchConfig) -> RGLRUSpec:
    r = cfg.rglru
    width = r.lru_width or cfg.d_model
    bd = StructureConfig(kind="block_diag", b=max(cfg.n_heads, 1), keep_ratio=1.0)
    return RGLRUSpec(
        cfg=cfg, width=width, conv_width=r.conv_width, c=r.c,
        in_x=make_linear(cfg.d_model, width, cfg.structure),
        in_gate=make_linear(cfg.d_model, width, cfg.structure),
        out=make_linear(width, cfg.d_model, cfg.structure),
        gate_a=make_linear(width, width, bd),
        gate_x=make_linear(width, width, bd),
    )


def rglru_init(spec: RGLRUSpec, key, dtype) -> Params:
    ks = jax.random.split(key, 6)
    w = spec.width
    # Λ init so that a^c·softplus(Λ) gives decay in ≈ (0.9, 0.999) (Griffin A.2).
    u = jax.random.uniform(ks[5], (w,), minval=0.9**2, maxval=0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / spec.c))  # softplus⁻¹(-log u / c)
    return {
        "in_x": L.linear_init(spec.in_x, ks[0], dtype),
        "in_gate": L.linear_init(spec.in_gate, ks[1], dtype),
        "out": L.linear_init(spec.out, ks[2], dtype),
        "gate_a": L.linear_init(spec.gate_a, ks[3], dtype, bias=True),
        "gate_x": L.linear_init(spec.gate_x, ks[4], dtype, bias=True),
        "conv_w": jnp.zeros((spec.conv_width, w), dtype=dtype)
        .at[-1].set(1.0),  # identity-ish init: current token passes through
        "conv_b": jnp.zeros((w,), dtype=dtype),
        "lam": lam.astype(jnp.float32),
    }


def rglru_axes(spec: RGLRUSpec) -> dict:
    return {
        "in_x": L.linear_axes(spec.in_x, out_axis="ffn"),
        "in_gate": L.linear_axes(spec.in_gate, out_axis="ffn"),
        "out": L.linear_axes(spec.out, in_axis="ffn", out_axis="fsdp_in"),
        "gate_a": {**L.linear_axes(spec.gate_a), "bias": (None,)},
        "gate_x": {**L.linear_axes(spec.gate_x), "bias": (None,)},
        "conv_w": (None, "ffn"),
        "conv_b": ("ffn",),
        "lam": ("ffn",),
    }


def rglru_quantize(spec: RGLRUSpec, params: Params, bits: int = 8) -> Params:
    """Quantize every structured linear, including the block-diagonal gates
    (conv / Λ stay float — O(width) vectors)."""
    qp = dict(params)
    for name in ("in_x", "in_gate", "out", "gate_a", "gate_x"):
        qp[name] = L.linear_quantize(getattr(spec, name), params[name], bits)
    return qp


def rglru_prestack(spec: RGLRUSpec, params: Params) -> Params:
    """Pre-stack the two grouped bundles (in_gate+in_x on the block input,
    gate_a+gate_x on the conv output) once at load."""
    p = dict(params)
    bi = L.linear_group_prestack((spec.in_gate, spec.in_x),
                                 (params["in_gate"], params["in_x"]))
    if bi is not None:
        p["_bundle_in"] = bi
    bg = L.linear_group_prestack((spec.gate_a, spec.gate_x),
                                 (params["gate_a"], params["gate_x"]))
    if bg is not None:
        p["_bundle_gate"] = bg
    return p


def _conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Causal depthwise conv via static shifts.  x: (B, T, C); w: (K, C)."""
    K = w.shape[0]
    y = x * w[-1]
    for k in range(1, K):
        shifted = jnp.pad(x[:, :-k], ((0, 0), (k, 0), (0, 0)))
        y = y + shifted * w[-1 - k]
    return y + b


def _rglru_scan(x: jax.Array, r: jax.Array, i: jax.Array, lam: jax.Array,
                c: float, h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """x, r, i: (B, T, W) → (h_seq, h_last), fp32 associative scan over T."""
    x, r, i = (t.astype(jnp.float32) for t in (x, r, i))
    log_a = -c * jax.nn.softplus(lam)[None, None, :] * jax.nn.sigmoid(r)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        jax.nn.sigmoid(i) * x)
    if h0 is not None:
        # fold the initial state into the first step: h_1 = a_1 h_0 + b_1
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h, h[:, -1]


def rglru_apply(spec: RGLRUSpec, params: Params, x: jax.Array,
                positions: jax.Array, parallel: Parallel = NO_PARALLEL,
                *, return_cache: bool = False):
    """x: (B, T, d_model) → (B, T, d_model) [, cache]."""
    # in_gate/in_x share x and gate_a/gate_x share u: two grouped launches
    gate_pre, u_pre = L.linear_group_apply(
        (spec.in_gate, spec.in_x), (params["in_gate"], params["in_x"]), x)
    gate = jax.nn.gelu(gate_pre)
    u_pre = parallel.constraint(u_pre, parallel.batch_spec(None, parallel.model_axis))
    u = _conv1d(u_pre, params["conv_w"], params["conv_b"])
    r, i = L.linear_group_apply(
        (spec.gate_a, spec.gate_x), (params["gate_a"], params["gate_x"]), u)
    h, h_last = _rglru_scan(u, r, i, params["lam"], spec.c)
    y = L.linear_apply(spec.out, params["out"], (h.astype(x.dtype) * gate))
    y = parallel.shard_batch(y)
    if not return_cache:
        return y
    # conv buffer stores the last K-1 PRE-conv branch inputs (decode contract)
    K = spec.conv_width
    u_tail = u_pre[:, -(K - 1):] if u_pre.shape[1] >= K - 1 else jnp.pad(
        u_pre, ((0, 0), (K - 1 - u_pre.shape[1], 0), (0, 0)))
    return y, qt.pack_state_cache(spec.cfg.cache_quant,
                                  u_tail.astype(x.dtype),
                                  h_last.astype(jnp.float32))


def rglru_cache_init(spec: RGLRUSpec, batch: int, max_len: int, dtype) -> Params:
    c: Params = {}
    if spec.cfg.cache_quant:
        c["conv"] = jnp.zeros((batch, spec.conv_width - 1, spec.width), jnp.int8)
        c["conv_scale"] = jnp.zeros((batch, spec.conv_width - 1), jnp.bfloat16)
        c["h"] = jnp.zeros((batch, spec.width), jnp.int8)
        c["h_scale"] = jnp.zeros((batch,), jnp.float32)
    else:
        c["conv"] = jnp.zeros((batch, spec.conv_width - 1, spec.width), dtype=dtype)
        c["h"] = jnp.zeros((batch, spec.width), dtype=jnp.float32)
    return c


def rglru_cache_axes(spec: RGLRUSpec) -> dict:
    a = {"conv": ("batch", None, "ffn"), "h": ("batch", "ffn")}
    if spec.cfg.cache_quant:
        a["conv_scale"] = ("batch", None)
        a["h_scale"] = ("batch",)
    return a


def rglru_prefill(spec: RGLRUSpec, params: Params, cache: Params, x: jax.Array,
                  steps: jax.Array, n_tokens: jax.Array,
                  parallel: Parallel = NO_PARALLEL, *,
                  collect: bool = False) -> tuple[jax.Array, Params]:
    """Multi-token prefill: batched structured projections + exact per-token
    recurrence (lax.scan over C, bit-matching C sequential decode steps).

    x: (B, C, d_model); n_tokens: (B,) live tokens per ragged row — dead
    columns neither advance (conv, h) nor contribute.  ``steps`` is unused
    (no positional state) but kept for the uniform mixer-prefill signature.

    ``collect=True`` additionally returns per-token state snapshots in the
    cache (``h_snap (B, C+1, W)`` with index 0 = the incoming state, and the
    full conv history ``conv_hist``) so a speculative verify step can be
    rolled back to any draft boundary (``rglru_cache_rollback``).
    """
    del steps
    B, C, _ = x.shape
    conv_prev, h_prev = qt.unpack_state_cache(spec.cfg.cache_quant,
                                              cache, x.dtype)
    gate_pre, u = L.linear_group_apply(
        (spec.in_gate, spec.in_x), (params["in_gate"], params["in_x"]), x,
        bundle=params.get("_bundle_in"))
    gate = jax.nn.gelu(gate_pre)                       # u: (B, C, W)
    valid = jnp.arange(C)[None, :] < n_tokens[:, None]

    # Conv and the block-diagonal gate projections are position-parallel:
    # run them over the whole chunk (this is where the structured matmuls
    # see (B·C) tokens), and scan only the 2-term h recurrence.
    from repro.models.ops import causal_conv_chunk
    u_conv, conv_f = causal_conv_chunk(conv_prev, u, params["conv_w"],
                                       params["conv_b"], n_tokens)
    r, i = L.linear_group_apply(
        (spec.gate_a, spec.gate_x), (params["gate_a"], params["gate_x"]),
        u_conv, bundle=params.get("_bundle_gate"))
    log_a = (-spec.c * jax.nn.softplus(params["lam"])[None, None, :]
             * jax.nn.sigmoid(r.astype(jnp.float32)))
    log_a = jnp.where(valid[..., None], log_a, 0.0)   # dead cols: a=1
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = beta * (jax.nn.sigmoid(i.astype(jnp.float32))
                    * u_conv.astype(jnp.float32))
    gated = jnp.where(valid[..., None], gated, 0.0)   # dead cols: h + 0

    def tok(h, inp):
        a_t, g_t = inp
        h_new = a_t * h + g_t
        return h_new, h_new

    h_f, hs = jax.lax.scan(tok, h_prev,
                           (a.transpose(1, 0, 2), gated.transpose(1, 0, 2)))
    hs = hs.transpose(1, 0, 2)                         # (B, C, W)
    y = L.linear_apply(spec.out, params["out"], hs.astype(x.dtype) * gate)
    new_cache = qt.pack_state_cache(spec.cfg.cache_quant, conv_f, h_f)
    if collect:
        new_cache["h_snap"] = jnp.concatenate(
            [h_prev.astype(jnp.float32)[:, None], hs], axis=1)  # (B, C+1, W)
        new_cache["conv_hist"] = jnp.concatenate([conv_prev, u], axis=1)
    return parallel.shard_batch(y), new_cache


def rglru_cache_rollback(spec: RGLRUSpec, cache: Params,
                         n_comm: jax.Array) -> Params:
    """Rewind a ``collect=True`` prefill's cache to its first ``n_comm``
    tokens.  The state after token n_comm is ``h_snap[:, n_comm]`` exactly
    (dead/rejected columns set a=1 and add 0, so snapshots at draft
    boundaries equal never having drafted), and the conv buffer is the K−1
    history entries ending at n_comm.  Re-packing through
    ``pack_state_cache`` reproduces the quantized-cache bits too."""
    h_snap, hist = cache["h_snap"], cache["conv_hist"]
    B = h_snap.shape[0]
    K1 = spec.conv_width - 1
    idx = n_comm[:, None] + jnp.arange(K1, dtype=n_comm.dtype)[None, :]
    conv = jnp.take_along_axis(hist, idx[:, :, None], axis=1)
    h = h_snap[jnp.arange(B), n_comm]
    return qt.pack_state_cache(spec.cfg.cache_quant, conv, h)


def rglru_decode(spec: RGLRUSpec, params: Params, cache: Params, x: jax.Array,
                 step: jax.Array, parallel: Parallel = NO_PARALLEL
                 ) -> tuple[jax.Array, Params]:
    """Single-token decode — ``rglru_prefill`` with C=1."""
    B = x.shape[0]
    return rglru_prefill(spec, params, cache, x,
                         jnp.zeros((B,), jnp.int32),
                         jnp.ones((B,), jnp.int32), parallel)
