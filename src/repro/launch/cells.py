"""(arch × shape) cell builders shared by dryrun, benchmarks and launchers.

A *cell* is one of the assigned grid entries: ``train_4k`` lowers the full
``train_step`` (fwd+bwd+AdamW), ``prefill_32k`` lowers the forward pass
(last-position logits, the serving prefill), ``decode_32k``/``long_500k``
lower ``decode_step`` (one token against a seq_len-deep cache).

Everything is ShapeDtypeStruct-based — no arrays are materialized, which is
what lets the 671B config lower on a CPU host."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCfg
from repro.models import build_model
from repro.optim import adamw, cosine_schedule
from repro.parallel import Parallel
from repro.train import make_train_step
from repro.launch import sharding as sh


@dataclasses.dataclass
class Cell:
    name: str
    fn: Callable
    args: tuple                      # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    static: dict                     # metadata for the roofline report


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeCfg) -> dict:
    """ShapeDtypeStruct stand-ins for the model inputs of this cell."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "train":
        batch = {"tokens": _sds((B, S + 1), jnp.int32)}
        if cfg.encoder is not None:
            batch["frames"] = _sds((B, cfg.encoder.n_frames, cfg.d_model), dt)
        elif cfg.embeds_input:
            batch["embeds"] = _sds((B, S, cfg.d_model), dt)
        return batch
    if shape.kind == "prefill":
        if cfg.encoder is not None:
            return {"tokens": _sds((B, S + 1), jnp.int32),
                    "frames": _sds((B, cfg.encoder.n_frames, cfg.d_model), dt)}
        if cfg.embeds_input:
            return {"embeds": _sds((B, S, cfg.d_model), dt)}
        return {"tokens": _sds((B, S), jnp.int32)}
    # decode
    return {"tokens": _sds((B, 1), jnp.int32), "step": _sds((), jnp.int32)}


def make_cell(cfg: ArchConfig, shape: ShapeCfg, parallel: Parallel) -> Cell:
    model = build_model(cfg, parallel)
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(model.init, key)
    params_sh = sh.tree_shardings(params_sds, model.axes(), parallel)
    batch_sds = input_specs(cfg, shape)
    name = f"{cfg.name}__{shape.name}"
    meta = {"arch": cfg.name, "shape": shape.name, "kind": shape.kind,
            "global_batch": shape.global_batch, "seq_len": shape.seq_len,
            "replication": sh.replication_report(params_sds, model.axes(),
                                                 parallel)}

    if shape.kind == "train":
        opt = adamw(cosine_schedule(3e-4, 10_000, 100),
                    state_dtype=jnp.dtype(cfg.optimizer_dtype))
        step_fn = make_train_step(model, opt)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_sh = sh.optimizer_shardings(opt_sds, params_sh, parallel)
        batch_sh = sh.batch_shardings(batch_sds, parallel)
        return Cell(name, step_fn, (params_sds, opt_sds, batch_sds),
                    (params_sh, opt_sh, batch_sh),
                    (params_sh, opt_sh, None), meta)

    if shape.kind == "prefill":
        if cfg.encoder is not None:
            def fn(params, batch):
                out = model.apply(params, batch["tokens"][:, :-1],
                                  batch["frames"], last_only=True)
                return out.logits
        elif cfg.embeds_input:
            def fn(params, batch):
                out = model.apply(params, embeds=batch["embeds"],
                                  last_only=True)
                return out.logits
        else:
            def fn(params, batch):
                out = model.apply(params, tokens=batch["tokens"],
                                  last_only=True)
                return out.logits
        batch_sh = sh.batch_shardings(batch_sds, parallel)
        return Cell(name, fn, (params_sds, batch_sds),
                    (params_sh, batch_sh), None, meta)

    # decode: one new token against a seq_len-deep cache
    B, S = shape.global_batch, shape.seq_len
    if cfg.encoder is not None:
        dt = jnp.dtype(cfg.compute_dtype)
        frames_sds = _sds((B, cfg.encoder.n_frames, cfg.d_model), dt)
        cache_sds = jax.eval_shape(
            lambda p, f: model.init_cache(p, f, S), params_sds, frames_sds)
    else:
        cache_sds = jax.eval_shape(lambda: model.init_cache(B, S))
    cache_sh = sh.tree_shardings(cache_sds, model.cache_axes(), parallel)
    tok_sh = sh.batch_shardings({"t": batch_sds["tokens"]}, parallel)["t"]
    step_sh = sh.tree_shardings(
        {"s": batch_sds["step"]}, {"s": ()}, parallel)["s"]

    def fn(params, cache, tokens, step):
        return model.decode_step(params, cache, tokens, step)

    return Cell(name, fn,
                (params_sds, cache_sds, batch_sds["tokens"], batch_sds["step"]),
                (params_sh, cache_sh, tok_sh, step_sh),
                (None, cache_sh), meta)


def lower_cell(cell: Cell):
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings)
    return jitted.lower(*cell.args)
