import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and dump the roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k [--multi-pod] [--all] [--out artifacts/dryrun]

For each cell:  jax.jit(step, in_shardings, out_shardings).lower(SDS...)
.compile() on the 16×16 (single-pod) or 2×16×16 (multi-pod) mesh; prints
``memory_analysis()`` (fits-per-device proof) and ``cost_analysis()``
(FLOPs/bytes) and writes a JSON artifact with the parsed collective bytes —
EXPERIMENTS.md §Dry-run/§Roofline read these files."""

import argparse
import json
import time
import traceback


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, structure: str | None = None,
             kv_quant: bool = False, verbose: bool = True) -> dict:
    # imports deferred: XLA_FLAGS must be set before jax initializes
    import dataclasses
    import jax
    from repro import configs
    from repro.configs import SHAPES, get, shape_applicable
    from repro.launch.cells import lower_cell, make_cell
    from repro.launch.mesh import make_parallel, make_production_mesh
    from repro.roofline import analyze_compiled, model_flops

    cfg = get(arch_name, structure)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if os.environ.get("REPRO_BLAST_TP") == "block":
        cfg = dataclasses.replace(
            cfg, structure=dataclasses.replace(cfg.structure, tp="block"),
            structure_ffn=(dataclasses.replace(cfg.structure_ffn, tp="block")
                           if cfg.structure_ffn else None))
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    record: dict = {"arch": arch_name, "shape": shape_name,
                    "structure": structure or cfg.structure.kind,
                    "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            tag = f"{arch_name}__{shape_name}__{record['mesh']}"
            with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                json.dump(record, f, indent=1)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    # serve layout (params TP-sharded, data-replicated — no per-token weight
    # all-gather) only when the replicated copy fits; giants like the 671B
    # keep the fully-sharded layout and amortize the gather over the batch.
    serve = False
    if shape.kind == "decode":
        import numpy as np
        from repro.models import build_model
        probe = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
        param_bytes = sum(np.prod(l.shape) * l.dtype.itemsize
                          for l in jax.tree.leaves(probe))
        tp = mesh.shape.get("model", 1)
        serve = param_bytes / tp < 8e9
    parallel = make_parallel(mesh, global_batch=shape.global_batch,
                             serve=serve)
    n_dev = mesh.size
    t0 = time.time()
    try:
        cell = make_cell(cfg, shape, parallel)
        rep = cell.static.get("replication")
        if rep is not None:
            # surface the previously-silent divisibility fallbacks: leaves
            # that wanted a mesh axis but stayed replicated
            record["replication"] = {
                **{k: rep[k] for k in ("total_bytes", "replicated_bytes",
                                       "replicated_frac",
                                       "replicated_leaves")},
                "leaves": sorted(rep["leaves"],
                                 key=lambda e: -e["nbytes"])[:16],
            }
            if verbose and rep["replicated_leaves"]:
                print(f"[dryrun]   replicated (indivisible dims): "
                      f"{rep['replicated_bytes'] / 1e6:.1f} MB across "
                      f"{rep['replicated_leaves']} leaves "
                      f"({rep['replicated_frac']:.1%} of params)")
        lowered = lower_cell(cell)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        terms = analyze_compiled(compiled)
        record.update(
            status="ok", devices=n_dev,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            roofline=terms.to_dict(),
        )
        if mem is not None:
            record["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        if verbose:
            print(f"[dryrun] {record['arch']} × {shape_name} "
                  f"({record['mesh']}): OK "
                  f"compute {terms.t_compute*1e3:.1f}ms "
                  f"memory {terms.t_memory*1e3:.1f}ms "
                  f"collective {terms.t_collective*1e3:.1f}ms "
                  f"→ {terms.dominant}-bound "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
            print(f"[dryrun]   memory_analysis: {record.get('memory')}")
    except Exception as e:  # a failure here is a bug in our sharding config
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch_name} × {shape_name}: FAILED {record['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch_name}__{shape_name}__{record['mesh']}"
        if structure:
            tag += f"__{structure}"
        if kv_quant:
            tag += "__kvq"
            record["kv_quant"] = True
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(record, f, indent=1, default=float)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--structure", default=None,
                    help="dense | blast50 | low_rank50 | monarch50 | ...")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (decode cells)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch × shape) cell")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    from repro import configs  # deferred

    results = []
    if args.all:
        for arch in configs.ASSIGNED:
            for shape in configs.SHAPES:
                results.append(run_cell(arch, shape, multi_pod=args.multi_pod,
                                        out_dir=args.out,
                                        structure=args.structure))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        results.append(run_cell(args.arch, args.shape,
                                multi_pod=args.multi_pod, out_dir=args.out,
                                structure=args.structure,
                                kv_quant=args.kv_quant))
    bad = [r for r in results if r["status"] == "error"]
    print(f"[dryrun] {len(results)} cells: "
          f"{sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skipped' for r in results)} skipped, "
          f"{len(bad)} failed")
    if bad:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
