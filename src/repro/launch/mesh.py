"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single-pod: 16×16 = 256 chips (v5e pod), axes
(data, model).  Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) —
the "pod" axis is the DCN dimension; params FSDP-shard over (pod, data),
TP/EP over "model"."""

from __future__ import annotations

import jax

from repro.parallel import Parallel


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_parallel(mesh, *, global_batch: int | None = None,
                  serve: bool = False) -> Parallel:
    """Build the Parallel context for a mesh.

    If ``global_batch`` is given and not divisible by the full DP domain,
    batch axes shrink (or drop) so activation sharding stays even — e.g.
    long_500k's B=1 runs batch-replicated with the model axes still sharded.

    ``serve=True`` disables FSDP parameter sharding (params TP-sharded,
    data-replicated): a decode step must not all-gather weights per token.
    """
    names = mesh.axis_names
    data_axes = tuple(a for a in names if a in ("pod", "data"))
    model_axis = "model" if "model" in names else None
    if global_batch is not None:
        while data_axes:
            size = 1
            for a in data_axes:
                size *= mesh.shape[a]
            if global_batch % size == 0:
                break
            data_axes = data_axes[1:]  # drop the outermost (pod) first
        if not data_axes:
            data_axes = ()
    return Parallel(mesh=mesh, data_axes=data_axes,
                    fsdp_axis="data", model_axis=model_axis,
                    fsdp_axes_override=() if serve else None)


def host_mesh() -> jax.sharding.Mesh:
    """1-device mesh for CPU smoke runs of the same code paths."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def parse_mesh(spec: str) -> tuple[int, int]:
    """``"dp,tp"`` → (dp, tp); a bare ``"N"`` means tp=N (the serving
    default — TP first, DP only when requested)."""
    parts = [p.strip() for p in str(spec).split(",") if p.strip()]
    try:
        if len(parts) == 1:
            return 1, int(parts[0])
        if len(parts) == 2:
            return int(parts[0]), int(parts[1])
    except ValueError:
        pass
    raise ValueError(f"--mesh expects 'dp,tp' (e.g. '1,8'), got {spec!r}")


def make_serving_mesh(dp: int = 1, tp: int = 1) -> jax.sharding.Mesh:
    """(dp × tp) serving mesh over the visible devices — the SAME axes
    ("data", "model") at every size, so one engine code path covers a
    single CPU device and an 8-chip slice (simulated meshes via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` work too).
    """
    n = dp * tp
    have = len(jax.devices())
    if n > have:
        raise ValueError(
            f"mesh {dp}x{tp} needs {n} devices but only {have} are visible "
            "(simulate with XLA_FLAGS=--xla_force_host_platform_device_"
            "count=N set before jax import)")
    return jax.make_mesh((dp, tp), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
