"""Serving launcher: chunked-prefill continuous-batching engine over any arch.

Batch smoke (default):

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --reduced --requests 8 --max-new 16 --chunk 16

Paged pool + multi-tenant trace with SLA report:

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --paged --pages 16 --page-size 16 --priority-classes 2 --trace \
        --report sla.json

HTTP/SSE frontend (stdlib asyncio, serves until interrupted):

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --paged --http-port 8080
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro import configs
from repro.launch.mesh import make_parallel, make_serving_mesh, parse_mesh
from repro.models import build_model
from repro.parallel import NO_PARALLEL
from repro.serve import (AutotuneConfig, Engine, EngineConfig, MemoryConfig,
                         Request, ResilienceConfig, SamplingParams,
                         SchedulerConfig, SpeculativeConfig)


def build_parallel(args):
    """``--mesh dp,tp`` → (Parallel, mesh string or None).

    A (1,1) mesh (or no flag) keeps the NO_PARALLEL fast path — identical
    traces to every earlier PR.  Anything larger builds the ("data",
    "model") serving mesh over the visible devices (simulate with
    XLA_FLAGS=--xla_force_host_platform_device_count=N) with serve=True
    parallelism: params TP-sharded + data-replicated, batch over "data".
    """
    spec = getattr(args, "mesh", None)
    if spec is None:
        return NO_PARALLEL, None
    dp, tp = parse_mesh(spec)
    if (dp, tp) == (1, 1):
        return NO_PARALLEL, None
    par = make_parallel(make_serving_mesh(dp, tp), serve=True)
    return par, f"{dp},{tp}"


def build_engine_config(args) -> EngineConfig:
    """Map the CLI surface onto an EngineConfig (API v2) — the launcher no
    longer touches the deprecated flat Engine kwargs."""
    return EngineConfig(
        mesh=getattr(args, "mesh", None),
        scheduler=SchedulerConfig(
            slots=args.slots, chunk_size=args.chunk,
            token_budget=args.token_budget,
            policy="priority" if args.priority_classes > 1 else "fifo",
            deadline_s=getattr(args, "deadline", None)),
        resilience=ResilienceConfig(
            watchdog_deadline_s=getattr(args, "watchdog", None),
            queue_high_water=getattr(args, "queue_high_water", None),
            heartbeat_s=getattr(args, "heartbeat", 10.0),
            fault_spec=getattr(args, "fault_plan", None)),
        memory=MemoryConfig(
            max_len=args.max_len, paged=args.paged, page_size=args.page_size,
            pages=args.pages),
        speculative=SpeculativeConfig(k=args.speculative,
                                      draft_rank_frac=args.draft_rank_frac),
        autotune=AutotuneConfig(enabled=args.autotune,
                                cache_path=args.autotune_cache),
        seed=args.seed)


def make_cli_trace(vocab, *, n_classes: int, max_new: int, seed: int):
    """Small multi-tenant trace: bulk class-(n-1) requests saturating the
    slots plus interactive class-0 arrivals sharing one prompt prefix.
    Returns [(arrival_tick, Request)] sorted by arrival."""
    key = jax.random.PRNGKey(seed + 17)
    shared = [int(t) for t in jax.random.randint(key, (48,), 0, vocab)]
    trace = []
    lo = max(0, n_classes - 1)
    for i in range(6):   # bulk: long generations, lowest priority
        p = [int(t) for t in jax.random.randint(
            jax.random.fold_in(key, i), (8,), 0, vocab)]
        trace.append((0, Request(uid=i, prompt=p, max_new_tokens=max_new * 2,
                                 priority=lo)))
    for i in range(8):   # interactive: shared 48-token prefix, short answers
        tail = [int(t) for t in jax.random.randint(
            jax.random.fold_in(key, 100 + i), (4,), 0, vocab)]
        trace.append((3 + 2 * i,
                      Request(uid=100 + i, prompt=shared + tail,
                              max_new_tokens=max_new, priority=0,
                              prefix_len=len(shared))))
    return sorted(trace, key=lambda a: a[0])


def run_trace(engine: Engine, trace) -> dict:
    """Drive the engine tick-by-tick, submitting each request at its
    arrival tick; returns the SLA report."""
    pending = list(trace)
    tick = 0
    while pending or engine.queue or any(
            s.req is not None for s in engine.slots):
        while pending and pending[0][0] <= tick:
            engine.submit(pending.pop(0)[1])
        engine.tick()
        tick += 1
    return engine.sla_report()


def _print_resilience(engine: Engine):
    """One line of chaos/degradation accounting after a run — silent when
    nothing tripped and no fault plan was armed."""
    rep = engine.resilience_report()
    tripped = any(rep[k] for k in ("numeric_trips", "step_errors", "shed",
                                   "deadline_expired"))
    if not tripped and "faults" not in rep:
        return
    h = rep["health"]
    print(f"[serve] resilience: health={h['state']}"
          f"{' (' + h['reason'] + ')' if h['reason'] else ''} — "
          f"{rep['numeric_trips']} guardrail trips "
          f"(spec_off {rep['degrade_spec_off']}, "
          f"act_float {rep['degrade_act_float']}, "
          f"failed {rep['numeric_error_failures']}), "
          f"{rep['step_errors']} step errors, {rep['requeues']} requeues, "
          f"{rep['shed']} shed, {rep['deadline_expired']} past deadline, "
          f"{h['watchdog_trips']} watchdog trips")
    if "faults" in rep:
        fr = rep["faults"]
        print(f"[serve] faults fired: {fr['fired']} of "
              f"{len(fr['planned'])} planned — {fr['fired_by_kind']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--structure", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=16,
                    help="prompt tokens one slot may prefill per step")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="max total tokens packed into one mixed batch")
    ap.add_argument("--paged", action="store_true",
                    help="page the KV/state cache: pool sized in tokens, "
                         "prefix sharing + preemption (serve/paged.py)")
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size (default: slots*ceil(max_len/"
                         "page_size)+1, i.e. slot-static parity)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per cache page (--paged)")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help=">1 enables priority scheduling: class 0 is most "
                         "urgent and may preempt higher classes under "
                         "page pressure (1 = FIFO)")
    ap.add_argument("--http-port", type=int, default=None,
                    help="serve an HTTP/SSE frontend on this port instead "
                         "of running a local batch (0 = ephemeral port)")
    ap.add_argument("--trace", action="store_true",
                    help="run the built-in multi-tenant trace (bulk + "
                         "shared-prefix interactive arrivals) and print "
                         "the SLA report")
    ap.add_argument("--quant-weights", default="none",
                    choices=["none", "int8", "int4"],
                    help="quantize-at-load weight storage")
    ap.add_argument("--quant-activations", default="none",
                    choices=["none", "int8"],
                    help="per-token int8 activation quantization: with "
                         "int8/int4 weights the BLAST layers run integer "
                         "W8A8/W4A8 kernels (requires --quant-weights)")
    ap.add_argument("--quant-cache", default="none", choices=["none", "int8"],
                    help="int8 KV/latent/state caches")
    ap.add_argument("--autotune", action="store_true",
                    help="time candidate BLAST kernel tilings at engine "
                         "build and cache the winners (kernels/autotune.py)")
    ap.add_argument("--autotune-cache", default=None,
                    help="autotune cache path (default .autotune/"
                         "blast_tiling.json)")
    ap.add_argument("--speculative", type=int, default=0, metavar="K",
                    help="self-speculative decoding: draft K tokens per "
                         "round with a rank-truncated copy of the model, "
                         "verify in one full-model chunk (0 = off)")
    ap.add_argument("--draft-rank-frac", type=float, default=0.5,
                    help="fraction of pooled spectral energy kept by the "
                         "draft model's rank-calibration (--speculative)")
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="device mesh as 'dp,tp' (bare N means tp=N): the "
                         "same engine code runs 1-device and multi-chip; "
                         "simulate chips on CPU with XLA_FLAGS=--xla_force_"
                         "host_platform_device_count=N")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="deterministic fault injection (serve/faults.py): "
                         "e.g. 'nan@6:u3;raise@12:u1;slow@20:0.5' — the "
                         "engine must finish every non-faulted request "
                         "token-identically")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="per-request end-to-end deadline in seconds "
                         "(stop_reason='deadline' past it)")
    ap.add_argument("--watchdog", type=float, default=None, metavar="S",
                    help="watchdog step deadline: a jitted step exceeding "
                         "this marks the engine degraded (GET /healthz)")
    ap.add_argument("--queue-high-water", type=int, default=None,
                    help="shed queued work above this many requests in "
                         "flight (HTTP answers 429 + Retry-After)")
    ap.add_argument("--heartbeat", type=float, default=10.0, metavar="S",
                    help="SSE heartbeat interval between tokens")
    ap.add_argument("--report", default=None,
                    help="write a JSON throughput/SLA report here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch, args.structure)
    if args.reduced:
        cfg = cfg.reduced()
    if (args.quant_weights != "none" or args.quant_cache != "none"
            or args.quant_activations != "none"):
        import dataclasses
        from repro.quant import QuantConfig
        cfg = dataclasses.replace(cfg, quant=QuantConfig(
            weights=args.quant_weights, cache=args.quant_cache,
            activations=args.quant_activations))
    if cfg.encoder is not None:
        raise SystemExit("use examples/serve_batched.py for enc-dec archs")
    parallel, _ = build_parallel(args)
    model = build_model(cfg, parallel)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = Engine(model, params, build_engine_config(args))
    if parallel.active:
        rep = engine.sharding_report or {}
        print(f"[serve] mesh {parallel.dp_size}x{parallel.tp_size} "
              f"(data x model) over {parallel.dp_size * parallel.tp_size} "
              f"devices — replicated params "
              f"{rep.get('replicated_bytes', 0) / 1e6:.2f} MB of "
              f"{rep.get('total_bytes', 0) / 1e6:.2f} MB "
              f"({rep.get('replicated_leaves', 0)} leaves)")
    if args.paged:
        pc = engine._pc
        print(f"[serve] paged: {pc.pages.n_pages} pages x {pc.ps} tokens "
              f"({pc.pool_tokens()} pool tokens vs "
              f"{args.slots * args.max_len} slot-static)")
    if args.speculative:
        plan = engine.draft_plan
        print(f"[serve] speculative k={args.speculative}: draft keeps "
              f"{sum(plan.values())} of the full model's ranks "
              f"({len(plan)} calibrated linears, "
              f"frac={args.draft_rank_frac})")
    if args.autotune:
        from repro.kernels import autotune
        cache = autotune.cache()
        print(f"[serve] autotune: {len(cache.entries)} tiling entries "
              f"cached at {cache.path}")

    if args.fault_plan:
        print(f"[serve] fault plan armed: "
              f"{'; '.join(f.describe() for f in engine.fault_plan.faults)}")

    if args.http_port is not None:
        import asyncio
        from repro.serve.http import run_server
        print(f"[serve] http/sse frontend on port {args.http_port} "
              f"(POST /v1/generate, GET /v1/metrics, GET /healthz)")
        asyncio.run(run_server(engine, port=args.http_port))
        return

    if args.trace:
        trace = make_cli_trace(cfg.vocab, n_classes=args.priority_classes,
                               max_new=args.max_new, seed=args.seed)
        t0 = time.perf_counter()
        sla = run_trace(engine, trace)
        dt = time.perf_counter() - t0
        done = engine.finished
        c0 = sla["classes"].get("0", {})
        print(f"[serve] trace: {len(done)} requests in {dt:.1f}s — "
              f"interactive TTFT p50 "
              f"{(c0.get('ttft_p50_s') or 0) * 1e3:.1f} ms "
              f"p99 {(c0.get('ttft_p99_s') or 0) * 1e3:.1f} ms, "
              f"preemptions {sla['preemptions']}, "
              f"prefix-hit {sla['prefix_hit_rate']:.2f}")
        _print_resilience(engine)
        if args.report:
            report = {"arch": args.arch, "requests": len(done), "wall_s": dt,
                      "paged": args.paged,
                      "priority_classes": args.priority_classes, "sla": sla}
            with open(args.report, "w") as f:
                json.dump(report, f, indent=2, sort_keys=True)
            print(f"[serve] report written to {args.report}")
        return

    key = jax.random.PRNGKey(args.seed + 1)
    prompts = []
    for i in range(args.requests):
        plen = 4 + (i % 5)
        prompt = jax.random.randint(jax.random.fold_in(key, i), (plen,),
                                    0, cfg.vocab)
        prompts.append([int(t) for t in prompt])
    # explicit small uids (1..N) so --fault-plan targets are addressable
    reqs = [Request(uid=i + 1, prompt=p, max_new_tokens=args.max_new)
            for i, p in enumerate(prompts)]
    t0 = time.perf_counter()
    for r in reqs:
        engine.submit(r)
    engine.run()
    done = reqs
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    tp = engine.throughput()
    print(f"[serve] {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s, "
          f"{args.slots} slots, chunk={args.chunk}, "
          f"{tp['steps']} jitted steps)")
    print(f"[serve] prefill {engine.stats['prefill_tokens']} toks "
          f"@ {tp['prefill_tok_s']:.1f} tok/s · "
          f"decode {engine.stats['decode_tokens']} toks "
          f"@ {tp['decode_tok_s']:.1f} tok/s")
    if args.speculative:
        print(f"[serve] speculative: {tp['spec_rounds']} rounds, "
              f"acceptance {tp['acceptance_rate']:.2f}, "
              f"{tp['tokens_per_round']:.2f} tok/round")
    _print_resilience(engine)
    if args.report:
        report = {"arch": args.arch, "requests": len(done),
                  "total_tokens": total_tokens, "wall_s": dt,
                  "tok_s": total_tokens / dt, "speculative": args.speculative,
                  "draft_rank_frac": args.draft_rank_frac, **tp}
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"[serve] report written to {args.report}")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt {len(r.prompt)} toks → {r.output[:8]}…")


if __name__ == "__main__":
    main()
