"""Serving launcher: chunked-prefill continuous-batching engine over any arch.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --reduced --requests 8 --max-new 16 --chunk 16
"""

from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.models import build_model
from repro.parallel import NO_PARALLEL
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--structure", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=16,
                    help="prompt tokens one slot may prefill per step")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="max total tokens packed into one mixed batch")
    ap.add_argument("--quant-weights", default="none",
                    choices=["none", "int8", "int4"],
                    help="quantize-at-load weight storage")
    ap.add_argument("--quant-cache", default="none", choices=["none", "int8"],
                    help="int8 KV/latent/state caches")
    ap.add_argument("--autotune", action="store_true",
                    help="time candidate BLAST kernel tilings at engine "
                         "build and cache the winners (kernels/autotune.py)")
    ap.add_argument("--autotune-cache", default=None,
                    help="autotune cache path (default .autotune/"
                         "blast_tiling.json)")
    ap.add_argument("--speculative", type=int, default=0, metavar="K",
                    help="self-speculative decoding: draft K tokens per "
                         "round with a rank-truncated copy of the model, "
                         "verify in one full-model chunk (0 = off)")
    ap.add_argument("--draft-rank-frac", type=float, default=0.5,
                    help="fraction of pooled spectral energy kept by the "
                         "draft model's rank-calibration (--speculative)")
    ap.add_argument("--report", default=None,
                    help="write a JSON throughput/acceptance report here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch, args.structure)
    if args.reduced:
        cfg = cfg.reduced()
    if args.quant_weights != "none" or args.quant_cache != "none":
        import dataclasses
        from repro.quant import QuantConfig
        cfg = dataclasses.replace(cfg, quant=QuantConfig(
            weights=args.quant_weights, cache=args.quant_cache))
    if cfg.encoder is not None:
        raise SystemExit("use examples/serve_batched.py for enc-dec archs")
    model = build_model(cfg, NO_PARALLEL)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = Engine(model, params, batch_slots=args.slots,
                    max_len=args.max_len, seed=args.seed,
                    chunk_size=args.chunk, token_budget=args.token_budget,
                    autotune=args.autotune, autotune_cache=args.autotune_cache,
                    speculative=args.speculative,
                    draft_rank_frac=args.draft_rank_frac)
    if args.speculative:
        plan = engine.draft_plan
        print(f"[serve] speculative k={args.speculative}: draft keeps "
              f"{sum(plan.values())} of the full model's ranks "
              f"({len(plan)} calibrated linears, "
              f"frac={args.draft_rank_frac})")
    if args.autotune:
        from repro.kernels import autotune
        cache = autotune.cache()
        print(f"[serve] autotune: {len(cache.entries)} tiling entries "
              f"cached at {cache.path}")
    key = jax.random.PRNGKey(args.seed + 1)
    for i in range(args.requests):
        plen = 4 + (i % 5)
        prompt = jax.random.randint(jax.random.fold_in(key, i), (plen,),
                                    0, cfg.vocab)
        engine.submit(Request(uid=i, prompt=[int(t) for t in prompt],
                              max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    tp = engine.throughput()
    print(f"[serve] {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s, "
          f"{args.slots} slots, chunk={args.chunk}, "
          f"{tp['steps']} jitted steps)")
    print(f"[serve] prefill {engine.stats['prefill_tokens']} toks "
          f"@ {tp['prefill_tok_s']:.1f} tok/s · "
          f"decode {engine.stats['decode_tokens']} toks "
          f"@ {tp['decode_tok_s']:.1f} tok/s")
    if args.speculative:
        print(f"[serve] speculative: {tp['spec_rounds']} rounds, "
              f"acceptance {tp['acceptance_rate']:.2f}, "
              f"{tp['tokens_per_round']:.2f} tok/round")
    if args.report:
        import json
        report = {"arch": args.arch, "requests": len(done),
                  "total_tokens": total_tokens, "wall_s": dt,
                  "tok_s": total_tokens / dt, "speculative": args.speculative,
                  "draft_rank_frac": args.draft_rank_frac, **tp}
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"[serve] report written to {args.report}")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt {len(r.prompt)} toks → {r.output[:8]}…")


if __name__ == "__main__":
    main()
