"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --batch 8 --seq 256 [--reduced] [--structure blast50] \
        [--ckpt /tmp/ckpt]

On this CPU container you run the ``--reduced`` configs (same code path as
production); on a real pod the same entry point builds the production mesh
and shards via launch/sharding.py (the dry-run proves those cells compile).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import configs
from repro.data import TokenStream
from repro.models import build_model
from repro.optim import adamw, cosine_schedule
from repro.parallel import NO_PARALLEL
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--structure", default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family config")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch, args.structure)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, NO_PARALLEL)
    opt = adamw(cosine_schedule(args.lr, args.steps, args.warmup))

    class _Data:
        stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                             global_batch=args.batch, seed=args.seed)

        def batch(self, step):
            b = self.stream.batch(step)
            if cfg.embeds_input and cfg.encoder is None:
                key = jax.random.fold_in(jax.random.PRNGKey(7), step)
                b["embeds"] = jax.random.normal(
                    key, (args.batch, args.seq, cfg.d_model))
            if cfg.encoder is not None:
                key = jax.random.fold_in(jax.random.PRNGKey(7), step)
                b["frames"] = jax.random.normal(
                    key, (args.batch, cfg.encoder.n_frames, cfg.d_model))
            return b

    trainer = Trainer(model, opt, _Data(), checkpoint_dir=args.ckpt,
                      checkpoint_every=args.ckpt_every,
                      microbatch=args.microbatch)
    result = trainer.run(args.steps, key=jax.random.PRNGKey(args.seed))
    hist = result["history"]
    print(f"[train] {args.arch} ({cfg.structure.kind}): "
          f"loss {hist[0]:.4f} → {hist[-1]:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
