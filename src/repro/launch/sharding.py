"""Logical-axis → mesh PartitionSpec rules (GSPMD).

Every param/cache leaf carries a tuple of *logical* axis names (built by the
model's ``axes()``); this module maps them onto mesh axes:

    vocab / heads / kv_heads / ffn / rank / model_out / experts  → "model"
    embed / fsdp_in / in_block / out_block                       → FSDP axes
    batch                                                        → DP axes
    expert_ffn / blocks / layers / None                          → replicated

"rank" → "model" is the BLAST tensor-parallel scheme (DESIGN.md §3): the
shared factors U/V/S all shard on the rank dimension, so stage-1/2 run fully
local and only the stage-3 output needs the TP all-reduce — the same
communication pattern as Megatron row-parallel, at (keep-ratio)× the bytes.

Assignment is greedy per-tensor with two safety rails: a mesh axis is used
at most once per tensor (e.g. MoE experts take "model", so the per-expert
BLAST rank falls back to replicated), and a dim must be divisible by the
axis size (else replicate that dim — predictable, no GSPMD padding
surprises)."""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel import Parallel

# logical axis name → role: "model" | "fsdp" | "data" | None
_ROLE = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "rank": "model",
    "model_out": "model",
    "experts": "model",
    "embed": "fsdp",
    "fsdp_in": "fsdp",
    "in_block": "fsdp",
    "out_block": "fsdp",
    "batch": "data",
    "kv_seq": "model",
    "expert_ffn": None,
    "blocks_tp": "model",
    "blocks": None,
    "blocks_j": None,
    "layers": None,
    None: None,
}


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def partition_spec(axes: tuple, shape: tuple, parallel: Parallel) -> P:
    """One tensor's PartitionSpec from its logical axes + global shape."""
    mesh = parallel.mesh
    role_to_mesh = {
        "model": parallel.model_axis,
        "fsdp": tuple(parallel.fsdp_axes) or None,
        "data": tuple(parallel.data_axes) or None,
    }
    used: set[str] = set()
    entries = []
    for dim, name in zip(shape, axes):
        mesh_axes = role_to_mesh.get(_ROLE.get(name))
        if mesh_axes is None:
            entries.append(None)
            continue
        flat = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
        if any(a in used for a in flat):
            entries.append(None)
            continue
        if dim % _axis_size(mesh, flat) != 0:
            # try a divisible suffix of the fsdp/data tuple before giving up
            while len(flat) > 1 and dim % _axis_size(mesh, flat) != 0:
                flat = flat[1:]
            if dim % _axis_size(mesh, flat) != 0:
                entries.append(None)
                continue
        used.update(flat)
        entries.append(flat[0] if len(flat) == 1 else flat)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _is_axes_leaf(x) -> bool:
    return x is None or (isinstance(x, tuple)
                         and all(e is None or isinstance(e, str) for e in x))


def tree_specs(shapes_tree, axes_tree, parallel: Parallel):
    """Congruent tree of PartitionSpecs from (eval_shape tree, axes tree)."""
    def one(axes, sds):
        if axes is None or sds is None:
            return P()
        return partition_spec(axes, sds.shape, parallel)
    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=_is_axes_leaf)


def tree_shardings(shapes_tree, axes_tree, parallel: Parallel):
    specs = tree_specs(shapes_tree, axes_tree, parallel)
    return jax.tree.map(lambda s: NamedSharding(parallel.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(batch_shapes: dict, parallel: Parallel):
    """Input batch: shard the leading (global batch) dim over the DP axes."""
    def one(sds):
        rest = (None,) * (len(sds.shape) - 1)
        return NamedSharding(parallel.mesh, parallel.batch_spec(*rest))
    return jax.tree.map(one, batch_shapes)


def optimizer_shardings(opt_shapes, param_shardings, parallel: Parallel):
    """AdamW m/v shard like the params; scalars replicated."""
    rep = NamedSharding(parallel.mesh, P())
    result = {}
    for k, v in opt_shapes.items():
        if k in ("m", "v", "gc_err") and v is not None:
            result[k] = jax.tree.map(lambda _, s: s, v, param_shardings)
        else:
            result[k] = jax.tree.map(lambda _: rep, v)
    return result
