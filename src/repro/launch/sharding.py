"""Logical-axis → mesh PartitionSpec rules (GSPMD).

Every param/cache leaf carries a tuple of *logical* axis names (built by the
model's ``axes()``); this module maps them onto mesh axes:

    vocab / heads / kv_heads / ffn / rank / model_out / experts  → "model"
    embed / fsdp_in / in_block / out_block                       → FSDP axes
    batch                                                        → DP axes
    expert_ffn / blocks / layers / None                          → replicated

"rank" → "model" is the BLAST tensor-parallel scheme (DESIGN.md §3): the
shared factors U/V/S all shard on the rank dimension, so stage-1/2 run fully
local and only the stage-3 output needs the TP all-reduce — the same
communication pattern as Megatron row-parallel, at (keep-ratio)× the bytes.

Assignment is greedy per-tensor with two safety rails: a mesh axis is used
at most once per tensor (e.g. MoE experts take "model", so the per-expert
BLAST rank falls back to replicated), and a dim must be divisible by the
axis size (else replicate that dim — predictable, no GSPMD padding
surprises).  Divisibility fallbacks are no longer silent: pass
``fallbacks=[]`` (or call ``replication_report``) to collect the leaves and
bytes that stayed replicated.

Quantized / grouped congruence
------------------------------
``tree_specs`` walks the *shapes* tree (eval_shape pytrees or live arrays)
and emits spec subtrees congruent with the two composite leaf kinds the
serving engine carries:

* ``QArray {q, scale}`` — the codes take the leaf's logical axes directly
  (divisibility is checked against the *stored* shape, so nibble-packed int4
  last dims are judged on their byte count); the scales follow their codes'
  axes wherever the scale dim equals the logical dim and replicate on the
  reduced (size-1) block axes.  Scale rows therefore land on the same mesh
  axes as the codes they dequantize.
* ``GroupBundle`` — prestacked grouped ``(G, …)`` operands are not in the
  model's ``axes()`` tree (they are built at engine load); their axes derive
  from the bundle's plan: the leading G (and any vmap "layers") dims
  replicate, blast factors shard the trailing rank dim ("rank" → "model",
  int4 bundles shard their packed byte axis), dense bundles shard
  ``model_out``, and the per-block scale vectors replicate.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.structures import GroupBundle
from repro.parallel import Parallel
from repro.quant.qarray import QArray

# logical axis name → role: "model" | "fsdp" | "data" | None
_ROLE = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "rank": "model",
    "model_out": "model",
    "experts": "model",
    "embed": "fsdp",
    "fsdp_in": "fsdp",
    "in_block": "fsdp",
    "out_block": "fsdp",
    "batch": "data",
    "kv_seq": "model",
    "expert_ffn": None,
    "blocks_tp": "model",
    "blocks": None,
    "blocks_j": None,
    "layers": None,
    None: None,
}

# trailing-dim logical axes of a GroupBundle's stacked arrays, by plan kind.
# Leading dims (the G group axis, plus a vmap "layers" axis for scan cycles)
# left-pad with None.  int4 blast bundles stack *packed* bytes: the rank
# entry then judges divisibility on the byte axis, which keeps nibble pairs
# on one shard (exact — the contraction is rank-permutation-invariant).
_BUNDLE_AXES = {
    "blast": {"U": ("blocks", "out_block", "rank"),
              "S": ("blocks", "blocks_j", "rank"),
              "V": ("blocks", "in_block", "rank"),
              "su": ("blocks",), "ss": ("blocks", "blocks_j"),
              "sv": ("blocks",)},
    "dense": {"W": ("fsdp_in", "model_out"), "sc": ("model_out",)},
    "block_diag": {"W": ("blocks", "in_block", "out_block"),
                   "sw": ("blocks",)},
}


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def partition_spec(axes: tuple, shape: tuple, parallel: Parallel,
                   *, fallbacks: list | None = None) -> P:
    """One tensor's PartitionSpec from its logical axes + global shape.

    ``fallbacks``: optional list collecting one record per dim that *wanted*
    a mesh role but replicated because the dim is not divisible by the axis
    size — the previously-silent case the dryrun/benchmark reports surface.
    """
    mesh = parallel.mesh
    role_to_mesh = {
        "model": parallel.model_axis,
        "fsdp": tuple(parallel.fsdp_axes) or None,
        "data": tuple(parallel.data_axes) or None,
    }
    used: set[str] = set()
    entries = []
    for dim, name in zip(shape, axes):
        mesh_axes = role_to_mesh.get(_ROLE.get(name))
        if mesh_axes is None:
            entries.append(None)
            continue
        flat = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
        if any(a in used for a in flat):
            entries.append(None)
            continue
        if dim % _axis_size(mesh, flat) != 0:
            # try a divisible suffix of the fsdp/data tuple before giving up
            while len(flat) > 1 and dim % _axis_size(mesh, flat) != 0:
                flat = flat[1:]
            if dim % _axis_size(mesh, flat) != 0:
                if fallbacks is not None and _axis_size(mesh, flat) > 1:
                    fallbacks.append({"axis": name, "dim": int(dim),
                                      "want": (flat[0] if len(flat) == 1
                                               else flat)})
                entries.append(None)
                continue
        used.update(flat)
        entries.append(flat[0] if len(flat) == 1 else flat)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _is_axes_leaf(x) -> bool:
    return x is None or (isinstance(x, tuple)
                         and all(e is None or isinstance(e, str) for e in x))


def _leaf_nbytes(sds) -> int:
    """Bytes of one array-like leaf (works on ShapeDtypeStructs too)."""
    if sds is None:
        return 0
    return math.prod(sds.shape) * np.dtype(sds.dtype).itemsize


def tree_specs(shapes_tree, axes_tree, parallel: Parallel,
               *, fallbacks: list | None = None):
    """Congruent tree of PartitionSpecs from (shapes tree, axes tree).

    ``shapes_tree`` may hold plain arrays / ShapeDtypeStructs, ``QArray``
    nodes, and prestacked ``GroupBundle`` nodes (the latter need no entry in
    ``axes_tree`` — their axes derive from the bundle plan).  The result has
    the same pytree structure as ``shapes_tree`` with a PartitionSpec at
    every array position, so it (and ``tree_shardings``) can be handed
    straight to ``jax.device_put`` / ``jax.jit``.
    """

    def spec_one(axes, sds, path):
        if sds is None or axes is None:
            return P()
        local: list = []
        spec = partition_spec(axes, sds.shape, parallel, fallbacks=local)
        if local and fallbacks is not None:
            fallbacks.append({"path": path, "nbytes": _leaf_nbytes(sds),
                              "dims": local})
        return spec

    def qarray_spec(axes, qa: QArray, path):
        if not _is_axes_leaf(axes) or axes is None:
            axes = (None,) * len(qa.shape)
        q_spec = spec_one(axes, qa.q, path + ".q")
        # scales follow their codes' axes where the dims match the logical
        # shape; reduced (size-1) block axes replicate
        logical = qa.shape
        s_axes = tuple(
            a if (i < len(logical)
                  and qa.scale.shape[i] == logical[i]) else None
            for i, a in enumerate(axes[:len(qa.scale.shape)]))
        s_spec = spec_one(s_axes, qa.scale, path + ".scale")
        return QArray(q_spec, s_spec, qa.bits, qa.last_dim)

    def bundle_spec(gb: GroupBundle, path):
        table = _BUNDLE_AXES[dict(gb.plan_items)["kind"]]
        arrays = {}
        for name, arr in gb.arrays.items():
            base = table.get(name, ())
            ax = (None,) * max(0, len(arr.shape) - len(base)) + base
            arrays[name] = spec_one(ax[:len(arr.shape)], arr,
                                    f"{path}.{name}")
        return GroupBundle(arrays, gb.plan_items)

    def rec(axes, sh, path):
        if isinstance(sh, GroupBundle):
            return bundle_spec(sh, path)
        if isinstance(sh, QArray):
            return qarray_spec(axes, sh, path)
        if isinstance(sh, dict):
            adict = axes if isinstance(axes, dict) else {}
            return {k: rec(adict.get(k), v, f"{path}/{k}")
                    for k, v in sh.items()}
        if isinstance(sh, (list, tuple)):
            alist = (axes if isinstance(axes, (list, tuple))
                     and not _is_axes_leaf(axes) else [None] * len(sh))
            return type(sh)(rec(a, v, f"{path}/{i}")
                            for i, (a, v) in enumerate(zip(alist, sh)))
        if _is_axes_leaf(axes) and axes is not None and hasattr(sh, "shape"):
            return spec_one(axes, sh, path)
        return P()

    return rec(axes_tree, shapes_tree, "")


def replication_report(shapes_tree, axes_tree, parallel: Parallel) -> dict:
    """Count + surface silently-replicated leaf bytes (divisibility
    fallbacks).  Consumed by the dryrun record and the mesh-sweep serving
    benchmark; an empty ``leaves`` list means every dim that wanted a mesh
    axis got one."""
    fallbacks: list = []
    tree_specs(shapes_tree, axes_tree, parallel, fallbacks=fallbacks)
    total = sum(_leaf_nbytes(l) for l in jax.tree.leaves(shapes_tree))
    rep = sum(e["nbytes"] for e in fallbacks)
    return {
        "total_bytes": int(total),
        "replicated_bytes": int(rep),
        "replicated_frac": (rep / total) if total else 0.0,
        "replicated_leaves": len(fallbacks),
        "leaves": [{"path": e["path"], "nbytes": int(e["nbytes"]),
                    "dims": e["dims"]} for e in fallbacks],
    }


def tree_shardings(shapes_tree, axes_tree, parallel: Parallel):
    specs = tree_specs(shapes_tree, axes_tree, parallel)
    return jax.tree.map(lambda s: NamedSharding(parallel.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(batch_shapes: dict, parallel: Parallel):
    """Input batch: shard the leading (global batch) dim over the DP axes."""
    def one(sds):
        rest = (None,) * (len(sds.shape) - 1)
        return NamedSharding(parallel.mesh, parallel.batch_spec(*rest))
    return jax.tree.map(one, batch_shapes)


def optimizer_shardings(opt_shapes, param_shardings, parallel: Parallel):
    """AdamW m/v shard like the params; scalars replicated."""
    rep = NamedSharding(parallel.mesh, P())
    result = {}
    for k, v in opt_shapes.items():
        if k in ("m", "v", "gc_err") and v is not None:
            result[k] = jax.tree.map(lambda _, s: s, v, param_shardings)
        else:
            result[k] = jax.tree.map(lambda _: rep, v)
    return result
