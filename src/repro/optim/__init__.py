from repro.optim.adamw import Optimizer, adamw, sgdm  # noqa: F401
from repro.optim.schedules import (  # noqa: F401
    constant_schedule, cosine_schedule, linear_schedule)
from repro.optim.compress import quantize_grads_int8  # noqa: F401
