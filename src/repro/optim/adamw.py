"""Pure-pytree optimizers (optax is not available offline).

AdamW with decoupled weight decay, global-norm clipping, configurable m/v
dtype (bf16 for the 671B config — halves optimizer-state HBM), and an
optional gradient-compression hook applied before the update (simulating a
quantized all-reduce with error feedback; see optim/compress.py).

The optimizer state is a plain pytree, so it shards/checkpoints/reshards
exactly like the params (same logical axes)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[..., tuple[Params, Any, dict]]


def adamw(schedule: Callable[[jax.Array], jax.Array], *, b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float = 1.0, state_dtype=jnp.float32,
          grad_transform: Callable | None = None) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
            "gc_err": (jax.tree.map(jnp.zeros_like, params)
                       if grad_transform is not None else None),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        gc_err = state["gc_err"]
        if grad_transform is not None:
            grads, gc_err = grad_transform(grads, gc_err)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)
        lr = schedule(count)
        t = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            m_new = b1 * m32 + (1 - b1) * g
            v_new = b2 * v32 + (1 - b2) * g * g
            step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
            if p.ndim >= 2:  # decay matrices only (norms/bias excluded)
                step = step + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * step
            return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        params_new = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        m_new = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        v_new = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": m_new, "v": v_new, "count": count, "gc_err": gc_err}
        metrics = {"grad_norm": gnorm, "lr": lr}
        return params_new, new_state, metrics

    return Optimizer(init=init, update=update)


def sgdm(schedule, *, momentum: float = 0.9, clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        lr = schedule(count)

        def upd(p, g, m):
            m_new = momentum * m + g.astype(m.dtype) * scale
            return (p - lr * m_new.astype(p.dtype)), m_new

        out = jax.tree.map(upd, params, grads, state["m"])
        params_new = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        m_new = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return params_new, {"m": m_new, "count": count}, {"grad_norm": gnorm,
                                                          "lr": lr}

    return Optimizer(init=init, update=update)
