"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_schedule(lr: float, total_steps: int, warmup: int = 0,
                    lr_end: float = 0.0):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        decay = lr + (lr_end - lr) * frac
        return jnp.where(step < warmup, warm, decay)
    return fn


def cosine_schedule(lr: float, total_steps: int, warmup: int = 0,
                    lr_min: float = 0.0):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        decay = lr_min + 0.5 * (lr - lr_min) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, decay)
    return fn
