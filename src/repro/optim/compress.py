"""Gradient compression for bandwidth-bound DP all-reduce at 1000+ nodes.

int8 symmetric per-tensor quantization with *error feedback* (the residual
from this round is added back next round, preserving convergence — Seide et
al. / EF-SGD).  In a real multi-host deployment the quantized tensor is what
crosses the DCN; under GSPMD we express the math and let the partitioner
place it — the roofline collective term scales by the 4× byte reduction
(recorded in EXPERIMENTS.md §Perf as an optional trick, off by default)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_grads_int8(grads, err):
    """→ (dequantized grads, new error-feedback residuals)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale   # <- this is what the wire carries
        return deq.astype(g.dtype), (g32 - deq).astype(e.dtype)

    out = jax.tree.map(one, grads, err)
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err
