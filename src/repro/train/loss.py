"""Loss functions per model family.

``make_loss_fn(model)`` returns ``loss_fn(params, batch) -> (loss, metrics)``
matched to the arch family:

  * LM families: next-token CE (+ MoE aux, + MTP t+2 CE for DeepSeek-V3)
  * enc-dec (whisper): teacher-forced decoder CE given stub frames
  * embeds-input (llava / vit backbone): CE over provided embeddings
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models import ops


def make_loss_fn(model, *, aux_weight: float = 0.01, mtp_weight: float = 0.3):
    cfg = model.cfg

    def lm_loss(params, batch):
        tokens = batch["tokens"]                     # (B, S+1)
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        if cfg.embeds_input and "embeds" in batch:
            out = model.apply(params, tokens=None, embeds=batch["embeds"])
        else:
            out = model.apply(params, tokens=inputs)
        loss, acc = ops.cross_entropy(out.logits, labels)
        total = loss + aux_weight * out.aux
        metrics = {"ce": loss, "acc": acc, "aux": out.aux}
        if out.mtp_logits is not None:
            # MTP head predicts token t+2 from position t
            mtp_loss, _ = ops.cross_entropy(
                out.mtp_logits[:, :-1], tokens[:, 2:])
            total = total + mtp_weight * mtp_loss
            metrics["mtp_ce"] = mtp_loss
        metrics["loss"] = total
        return total, metrics

    def encdec_loss(params, batch):
        tokens, frames = batch["tokens"], batch["frames"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        out = model.apply(params, inputs, frames)
        loss, acc = ops.cross_entropy(out.logits, labels)
        return loss, {"ce": loss, "acc": acc, "loss": loss}

    def vit_loss(params, batch):
        logits = model.apply(params, batch["patches"])
        loss, acc = ops.cross_entropy(logits, batch["labels"])
        return loss, {"ce": loss, "acc": acc, "loss": loss}

    if cfg.encoder is not None:
        return encdec_loss
    if cfg.family == "vision":
        return vit_loss
    return lm_loss
