"""Fault-tolerant training loop.

``make_train_step`` builds the jitted (or pjit-sharded) step:
value_and_grad → optional microbatch gradient accumulation (lax.scan) →
optimizer update → **NaN/overflow guard** (a non-finite loss or grad norm
skips the update instead of poisoning the params — the step still counts so
the data pipeline stays aligned).

``Trainer`` adds the operational layer a 1000-node run needs:
  * checkpoint/restart: resumes from the latest manifest (params, opt state,
    step) — the counter-indexed data pipeline replays nothing;
  * preemption hook: SIGTERM triggers a final checkpoint before exit;
  * straggler watchdog: EMA of step time, logs any step > ``watchdog_x``×
    the EMA (on a real cluster this feeds the reshard/evict decision);
  * async checkpoint commits off the critical path.
"""

from __future__ import annotations

import signal
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.optim import Optimizer
from repro.train.loss import make_loss_fn


def make_train_step(model, optimizer: Optimizer, *, microbatch: int = 0,
                    donate: bool = True, loss_fn: Callable | None = None):
    """→ jitted ``step(params, opt_state, batch) -> (params, opt, metrics)``.

    ``microbatch > 0`` splits the batch into that many accumulation chunks.
    """
    loss_fn = loss_fn or make_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatch and microbatch > 1:
            def one(carry, mb):
                (loss_acc, g_acc, m_acc) = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
                return (loss_acc + loss, g_acc, m_acc), None

            mbs = jax.tree.map(
                lambda x: x.reshape(microbatch, x.shape[0] // microbatch,
                                    *x.shape[1:]), batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mb0 = jax.tree.map(lambda x: x[0], mbs)
            (_, m0), _ = jax.eval_shape(grad_fn, params, mb0)
            zero_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
            (loss, grads, metrics), _ = jax.lax.scan(
                one, (jnp.zeros(()), zero_g, zero_m), mbs)
            inv = 1.0 / microbatch
            return (jax.tree.map(lambda g: g * inv, grads),
                    jax.tree.map(lambda m: m * inv, metrics))
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def step(params, opt_state, batch):
        grads, metrics = compute_grads(params, batch)
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, params)
        metrics = {**metrics, **opt_metrics}
        # NaN/overflow guard: skip the update, keep counting.
        good = jnp.isfinite(metrics["loss"]) & jnp.isfinite(metrics["grad_norm"])
        pick = lambda new, old: jax.tree.map(
            lambda n, o: jnp.where(good, n, o), new, old)
        params = pick(new_params, params)
        opt_state = {**pick({k: v for k, v in new_opt.items() if k != "count"},
                            {k: v for k, v in opt_state.items() if k != "count"}),
                     "count": new_opt["count"]}
        metrics["skipped"] = (~good).astype(jnp.float32)
        return params, opt_state, metrics

    return step


class Trainer:
    def __init__(self, model, optimizer: Optimizer, data, *,
                 checkpoint_dir: str | None = None, checkpoint_every: int = 50,
                 microbatch: int = 0, watchdog_x: float = 3.0,
                 jit: bool = True, log_every: int = 10,
                 log_fn: Callable[[str], None] = print):
        self.model = model
        self.optimizer = optimizer
        self.data = data
        self.step_fn = make_train_step(model, optimizer, microbatch=microbatch)
        if jit:
            self.step_fn = jax.jit(self.step_fn, donate_argnums=(0, 1))
        self.ckpt = (CheckpointManager(checkpoint_dir)
                     if checkpoint_dir else None)
        self.checkpoint_every = checkpoint_every
        self.watchdog_x = watchdog_x
        self.log_every = log_every
        self.log = log_fn
        self._preempted = False

    def _install_preemption_hook(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on the main thread (tests)

    def run(self, n_steps: int, key=None) -> dict[str, Any]:
        key = key if key is not None else jax.random.PRNGKey(0)
        params = self.model.init(key)
        opt_state = self.optimizer.init(params)
        start = 0
        if self.ckpt is not None:
            restored, step = self.ckpt.restore_latest(
                {"params": params, "opt": opt_state})
            if restored is not None:
                params, opt_state = restored["params"], restored["opt"]
                start = step + 1
                self.log(f"[trainer] resumed from step {step}")
        self._install_preemption_hook()
        ema = None
        history = []
        metrics = {}
        for step in range(start, n_steps):
            batch = self.data.batch(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > self.watchdog_x * ema and step > start + 3:
                self.log(f"[watchdog] step {step} took {dt:.2f}s "
                         f"({dt/ema:.1f}× EMA) — straggler suspected")
            if step % self.log_every == 0:
                self.log(f"[trainer] step {step} loss {float(metrics['loss']):.4f} "
                         f"acc {float(metrics.get('acc', 0)):.3f} {dt*1e3:.0f}ms")
            history.append(float(metrics["loss"]))
            if self.ckpt is not None and (
                    (step + 1) % self.checkpoint_every == 0 or self._preempted
                    or step + 1 == n_steps):
                self.ckpt.save(step, {"params": params, "opt": opt_state})
            if self._preempted:
                self.log(f"[trainer] preempted at step {step}; checkpointed")
                break
        if self.ckpt is not None:
            self.ckpt.wait()
        return {"params": params, "opt_state": opt_state,
                "history": history, "final_metrics": metrics}
