from repro.train.loss import make_loss_fn  # noqa: F401
from repro.train.trainer import Trainer, make_train_step  # noqa: F401
