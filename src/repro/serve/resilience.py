"""Serving resilience: numeric guardrails, engine health, watchdog, backoff.

The engine's fast paths all trade something for speed — W4A8 integer
contractions round activations, the speculative draft runs rank-truncated
weights, the paged pool recomputes preempted state — and each is a place a
numeric fault or a hung dispatch can originate.  This module holds the
pieces that let those paths fail *safely*:

  * ``Guardrail``  — one tiny jitted reduction over the step's logits
    returning a per-row ok bit (finite and |logit| ≤ absmax).  Costs one
    (B,) bool transfer per step; the full logits never come host-side for
    the check.  A tripped row walks the engine's degradation ladder
    (``DEGRADE_LADDER``) instead of poisoning the batch.
  * ``Health``     — the engine's externally visible condition
    (``ok | degraded | draining``) plus the trip/error counters the
    ``/healthz`` endpoint and the chaos benchmark report.
  * ``Watchdog``   — a daemon thread watching the engine's in-flight step
    timestamp: a step exceeding ``deadline_s`` (hung compile, stuck
    dispatch, injected stall) marks the engine degraded *from outside the
    engine lock*, so health checks and admission decisions keep answering
    while the step is stuck.  The next on-deadline step clears the state.
  * ``Backoff``    — deterministic jittered exponential backoff; the HTTP
    frontend derives ``Retry-After`` values from it so retrying clients
    spread out instead of thundering back.

The degradation ladder (per request, advanced one rung per guardrail trip):

    rung 0  full fast path
    rung 1  speculative decoding disabled for this request (the cheapest
            accuracy-for-speed trade is the first to go)
    rung 2  activation quantization disabled: the request's steps run the
            float-activation trace (W8/W4 weights stay quantized — only the
            per-token int8 rounding is removed), isolated from rung-0/1
            rows so *their* tokens stay bit-identical
    rung 3  the request alone fails with ``stop_reason="numeric_error"``

Every rung re-queues the request through the engine's deterministic
recompute-on-resume path, so a poisoned cache row is rebuilt from tokens,
never patched in place.
"""

from __future__ import annotations

import threading
import time
import traceback

import jax
import numpy as np

# rung index → what the engine turns off at that rung (rung 0 is the full
# fast path; a trip past the last rung fails the request)
DEGRADE_LADDER = ("spec_off", "act_float")


class Guardrail:
    """Jitted per-row finiteness/abs-max check on a step's logits."""

    def __init__(self, absmax: float | None = 1e6):
        self.absmax = absmax
        from repro.core import structures
        self._check = jax.jit(
            lambda lg: structures.row_health(lg, absmax=absmax))

    def ok_rows(self, logits) -> np.ndarray:
        """(B,) bool — False rows tripped the guardrail."""
        return np.asarray(self._check(logits))


class Health:
    """Engine condition surfaced to ``/healthz`` and the chaos report.

    Mutated from the engine thread (step timings, errors) and the watchdog
    thread (trips); all writes are single-attribute stores guarded by a
    small lock so readers always see a consistent (state, reason) pair."""

    def __init__(self):
        self._lock = threading.Lock()
        self.state = "ok"          # ok | degraded | draining
        self.reason: str | None = None
        self.watchdog_trips = 0
        self.step_errors = 0
        self.numeric_trips = 0
        self.last_errors: list[str] = []   # most recent tracebacks (ring)
        self.degraded_s = 0.0              # total wall time spent degraded
        self._degraded_at: float | None = None

    def degrade(self, reason: str):
        with self._lock:
            if self.state == "ok":
                self._degraded_at = time.monotonic()
            self.state = "degraded"
            self.reason = reason

    def recover(self):
        with self._lock:
            if self.state == "degraded":
                if self._degraded_at is not None:
                    self.degraded_s += time.monotonic() - self._degraded_at
                    self._degraded_at = None
                self.state = "ok"
                self.reason = None

    def drain(self):
        with self._lock:
            self.state = "draining"
            self.reason = "draining"

    def record_error(self, exc: BaseException, *, keep: int = 8):
        with self._lock:
            self.step_errors += 1
            tb = "".join(traceback.format_exception(
                type(exc), exc, exc.__traceback__))
            self.last_errors.append(tb)
            del self.last_errors[:-keep]

    def snapshot(self) -> dict:
        with self._lock:
            return {"state": self.state, "reason": self.reason,
                    "watchdog_trips": self.watchdog_trips,
                    "step_errors": self.step_errors,
                    "numeric_trips": self.numeric_trips,
                    "degraded_s": round(self.degraded_s, 6)}


class Watchdog:
    """Daemon thread tripping the engine's health when a step overruns.

    The engine stamps ``engine._step_inflight_since`` (monotonic) around
    every jitted dispatch; the watchdog polls it WITHOUT taking the engine
    lock — a hung step holds that lock, and the whole point is to keep
    answering health checks while it does.  One trip per overrunning step;
    the engine clears the degraded state itself when a later step finishes
    inside the deadline."""

    def __init__(self, engine, deadline_s: float):
        self.engine = engine
        self.deadline_s = float(deadline_s)
        self._stop = threading.Event()
        self._tripped_step_start: float | None = None
        self._thread = threading.Thread(
            target=self._run, name="engine-watchdog", daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=1.0)

    def _run(self):
        interval = max(0.005, min(0.05, self.deadline_s / 4))
        while not self._stop.wait(interval):
            since = self.engine._step_inflight_since
            if since is None:
                self._tripped_step_start = None
                continue
            if (time.monotonic() - since > self.deadline_s
                    and self._tripped_step_start != since):
                self._tripped_step_start = since    # one trip per step
                health = self.engine.health
                with health._lock:
                    health.watchdog_trips += 1
                health.degrade(
                    f"watchdog: step exceeded {self.deadline_s}s deadline")


class Backoff:
    """Jittered exponential backoff, deterministic under a seed.

    ``delay(attempt)`` = jitter · min(cap, base · 2^attempt) with jitter
    uniform in [0.5, 1) — "equal jitter", so consecutive retries never
    collapse to the same instant yet stay bounded.  The HTTP frontend keeps
    one instance and advances ``attempt`` while the engine stays
    overloaded, resetting on the first accepted request."""

    def __init__(self, base_s: float = 0.5, cap_s: float = 30.0,
                 seed: int = 0):
        self.base_s = float(base_s)
        self.cap_s = float(cap_s)
        self._rng = np.random.default_rng(seed)

    def delay(self, attempt: int) -> float:
        raw = min(self.cap_s, self.base_s * (2.0 ** max(0, int(attempt))))
        return raw * (0.5 + 0.5 * float(self._rng.random()))


def bisect_groups(uids: list[int]) -> list[list[int]]:
    """Split a suspect uid list into the two halves the driver probes when
    a step fails without naming its culprit (order-preserving)."""
    mid = max(1, len(uids) // 2)
    return [list(uids[:mid]), list(uids[mid:])] if len(uids) > 1 \
        else [list(uids)]
