"""Deterministic fault injection for the serving engine.

A ``FaultPlan`` is a seeded, fully deterministic list of faults the engine
polls at fixed points of its step loop — the chaos half of the resilience
layer (serve/resilience.py is the recovery half).  Four fault kinds:

  * ``nan_logits``   poison one request's logit row at a given step: the
                     engine's numeric guardrail must trip and walk the
                     degradation ladder (speculative off → activation quant
                     off → ``numeric_error``) without touching other rows.
  * ``driver_error`` raise inside the step loop whenever the target uid is
                     scheduled (persists until the engine isolates and
                     fails it — exercised by the batch bisect, since the
                     exception does not name its uid unless ``known``).
  * ``slow_step``    stall one step by ``delay_s`` (a hung compile or
                     dispatch): the watchdog must mark the engine degraded
                     instead of silently wedging every stream.
  * ``drop_conn``    client-side: the HTTP chaos client hangs up after N
                     SSE events.  The engine never polls this kind; it is
                     carried in the plan so one spec string describes the
                     whole scenario.

Spec grammar (``--fault-plan`` / ``ResilienceConfig.fault_spec``) — entries
separated by ``;`` or ``,``:

    nan@STEP:uUID[:xCOUNT]     nan_logits at step STEP for uid UID, fires
                               COUNT times (default 1; each firing trips
                               one rung of the ladder)
    raise@STEP:uUID[:known]    driver_error from step STEP while UID is
                               scheduled; ``known`` attaches the uid to the
                               exception (skips the bisect)
    slow@STEP:SECONDS          one SECONDS-long stall at step STEP
    drop@N[:uUID]              client disconnect after N stream events

Example: ``nan@6:u3;raise@12:u1;slow@20:0.5;drop@2:u4``.

``FaultPlan.seeded`` draws the same shape of plan from a PRNG —
``seeded(s, uids)`` twice yields identical plans, which is what the
determinism tests pin.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

KINDS = ("nan_logits", "driver_error", "slow_step", "drop_conn")


class FaultError(RuntimeError):
    """Raised by an armed ``driver_error`` fault.  ``uid`` is None unless
    the fault was declared ``known`` — the engine must bisect the batch to
    find the culprit, exactly as it would for a real opaque XLA error."""

    def __init__(self, msg: str, uid: int | None = None):
        super().__init__(msg)
        self.uid = uid


@dataclasses.dataclass
class Fault:
    kind: str                 # one of KINDS
    step: int                 # first engine iteration at/after which it arms
    uid: int | None = None    # target request (nan/raise/drop)
    delay_s: float = 0.0      # slow_step stall
    count: int = 1            # nan_logits firings (ladder rungs to climb)
    known: bool = False       # driver_error carries its uid
    events: int = 0           # drop_conn: hang up after this many SSE events
    fired: int = 0            # times this fault actually fired

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")

    def describe(self) -> str:
        tgt = f" uid={self.uid}" if self.uid is not None else ""
        extra = {"slow_step": f" delay={self.delay_s}s",
                 "nan_logits": f" x{self.count}",
                 "drop_conn": f" after={self.events}ev"}.get(self.kind, "")
        return f"{self.kind}@{self.step}{tgt}{extra}"


class FaultPlan:
    """Ordered fault list + a fire log.  ``poll(kind, step, uids)`` returns
    the faults of that kind due *now* and records each firing with a
    wall-clock timestamp (the chaos benchmark derives recovery latency from
    the log and the faulted requests' completion times)."""

    def __init__(self, faults: list[Fault]):
        self.faults = list(faults)
        self.log: list[dict] = []

    # -- construction --------------------------------------------------------

    @classmethod
    def from_spec(cls, spec: str) -> "FaultPlan":
        faults = []
        for raw in spec.replace(",", ";").split(";"):
            entry = raw.strip()
            if not entry:
                continue
            head, _, rest = entry.partition("@")
            parts = rest.split(":")
            if not head or not parts[0]:
                raise ValueError(f"bad fault entry {entry!r}")
            step = int(parts[0])
            args = parts[1:]
            if head == "nan":
                uid, count = None, 1
                for a in args:
                    if a.startswith("u"):
                        uid = int(a[1:])
                    elif a.startswith("x"):
                        count = int(a[1:])
                    else:
                        raise ValueError(f"bad nan arg {a!r} in {entry!r}")
                if uid is None:
                    raise ValueError(f"nan fault needs a :uUID in {entry!r}")
                faults.append(Fault("nan_logits", step, uid=uid, count=count))
            elif head == "raise":
                uid, known = None, False
                for a in args:
                    if a.startswith("u"):
                        uid = int(a[1:])
                    elif a == "known":
                        known = True
                    else:
                        raise ValueError(f"bad raise arg {a!r} in {entry!r}")
                if uid is None:
                    raise ValueError(
                        f"raise fault needs a :uUID in {entry!r}")
                faults.append(Fault("driver_error", step, uid=uid,
                                    known=known))
            elif head == "slow":
                if len(args) != 1:
                    raise ValueError(f"slow fault wants @STEP:SECONDS, "
                                     f"got {entry!r}")
                faults.append(Fault("slow_step", step,
                                    delay_s=float(args[0])))
            elif head == "drop":
                uid = None
                for a in args:
                    if a.startswith("u"):
                        uid = int(a[1:])
                    else:
                        raise ValueError(f"bad drop arg {a!r} in {entry!r}")
                faults.append(Fault("drop_conn", 0, uid=uid, events=step))
            else:
                raise ValueError(f"unknown fault kind {head!r} in {entry!r}")
        return cls(faults)

    @classmethod
    def seeded(cls, seed: int, uids: list[int], *, n: int = 4,
               max_step: int = 32,
               kinds: tuple = ("nan_logits", "driver_error",
                               "slow_step")) -> "FaultPlan":
        """Draw ``n`` faults deterministically from ``seed`` — same seed,
        same uid list → byte-identical plan."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n):
            kind = kinds[int(rng.integers(len(kinds)))]
            step = int(rng.integers(1, max_step))
            if kind == "slow_step":
                faults.append(Fault(kind, step,
                                    delay_s=round(0.05
                                                  + 0.2 * rng.random(), 3)))
            else:
                uid = int(uids[int(rng.integers(len(uids)))])
                if kind == "nan_logits":
                    faults.append(Fault(kind, step, uid=uid,
                                        count=int(rng.integers(1, 3))))
                else:
                    faults.append(Fault(kind, step, uid=uid))
        return cls(faults)

    # -- engine-side polling -------------------------------------------------

    def poll(self, kind: str, step: int, uids) -> list[Fault]:
        """Faults of ``kind`` due at engine iteration ``step`` given the
        scheduled ``uids``.  nan/slow faults fire ``count``/once; a
        driver_error stays armed while its uid keeps getting scheduled
        (the isolation machinery is what de-schedules it)."""
        due = []
        uids = set(uids)
        for f in self.faults:
            if f.kind != kind or step < f.step:
                continue
            if f.kind == "slow_step":
                if f.fired >= 1:
                    continue
            elif f.kind == "nan_logits":
                if f.fired >= f.count or f.uid not in uids:
                    continue
            elif f.kind == "driver_error":
                if f.uid not in uids:
                    continue
            else:          # drop_conn is client-side, never engine-polled
                continue
            f.fired += 1
            self.log.append({"kind": f.kind, "step": step, "uid": f.uid,
                             "t": time.perf_counter(),
                             "fault": f.describe()})
            due.append(f)
        return due

    # -- reporting -----------------------------------------------------------

    def faulted_uids(self) -> set[int]:
        return {f.uid for f in self.faults if f.uid is not None}

    def report(self) -> dict:
        by_kind: dict[str, int] = {}
        for e in self.log:
            by_kind[e["kind"]] = by_kind.get(e["kind"], 0) + 1
        return {"planned": [f.describe() for f in self.faults],
                "fired": len(self.log), "fired_by_kind": by_kind,
                "log": [dict(e) for e in self.log]}
