"""Async HTTP/SSE serving frontend — stdlib asyncio only, no new deps.

A deliberately small HTTP/1.1 surface over ``Engine.generate``:

  POST /v1/generate     body: {"prompt": [int token ids], "max_new_tokens",
                        "temperature", "priority", "prefix_len",
                        "deadline_s"} → ``text/event-stream`` of one SSE
                        event per token (``data: {"token": t}``), terminated
                        by ``data: {"done": true, "stop_reason": ...}``.
                        While the engine is overloaded (admission control
                        above ``ResilienceConfig.queue_high_water``) the
                        request is rejected up front with 429 + a jittered
                        exponential ``Retry-After``; a draining engine
                        answers 503.
  GET  /v1/metrics      JSON: throughput + SLA report (TTFT/TPOT
                        percentiles per priority class, preemption and
                        prefix-hit rates, queue depth, pool occupancy).
  GET  /healthz         JSON health snapshot (engine state ok | degraded |
                        draining, queue depth, active slots, pool
                        occupancy, watchdog/error counters).  200 while
                        ``ok`` or merely ``degraded`` (the engine is still
                        serving), 503 + Retry-After when draining.
  GET  /health          200 ok (legacy liveness probe; /healthz is the
                        informative one).

Error bodies are structured JSON — ``{"error": {"type", "reason"}}`` —
distinguishing client mistakes (400: the reason names the offending field)
from server faults (500: the reason is generic, the traceback goes to the
``repro.serve.http`` logger, never to the client).

Streams emit an SSE comment heartbeat (``: hb``) every
``ResilienceConfig.heartbeat_s`` while the engine is between tokens, so
proxies and clients can tell a slow generation from a dead connection.
Client disconnect mid-stream is detected on the next write; the
generator's cleanup path cancels the request, which releases its pages and
resets its slot (including the speculative draft-cache row) immediately.
"""

from __future__ import annotations

import asyncio
import json
import logging
import math

from repro.serve import resilience as rsl
from repro.serve.config import SamplingParams

_MAX_BODY = 1 << 20
log = logging.getLogger("repro.serve.http")


class _BadRequest(ValueError):
    """Client error: ``reason`` becomes the 400 body's error.reason."""


def _error_body(type_: str, reason: str) -> bytes:
    return json.dumps({"error": {"type": type_, "reason": reason}}).encode()


def _http(status: str, ctype: str, body: bytes, *, stream: bool = False,
          extra: dict | None = None):
    head = f"HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n"
    for k, v in (extra or {}).items():
        head += f"{k}: {v}\r\n"
    head += ("Cache-Control: no-store\r\nConnection: close\r\n\r\n" if stream
             else f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n")
    return head.encode() + body


async def _read_request(reader: asyncio.StreamReader):
    """Parse request line + headers + (Content-Length) body; None on EOF
    or malformed input."""
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
        return None
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split(" ")
    if len(parts) < 2:
        return None
    method, path = parts[0].upper(), parts[1]
    headers = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    body = b""
    n = int(headers.get("content-length", 0) or 0)
    if n:
        if n > _MAX_BODY:
            return None
        body = await reader.readexactly(n)
    return method, path, headers, body


def _parse_generate(body: bytes) -> dict:
    """Validate the /v1/generate body; raises _BadRequest naming the field."""
    try:
        spec = json.loads(body or b"{}")
    except ValueError:
        raise _BadRequest("body: not valid JSON")
    if not isinstance(spec, dict):
        raise _BadRequest("body: expected a JSON object")
    if "prompt" not in spec:
        raise _BadRequest("prompt: missing (non-empty token id list)")
    try:
        prompt = [int(t) for t in spec["prompt"]]
    except (TypeError, ValueError):
        raise _BadRequest("prompt: expected a list of integer token ids")
    if not prompt:
        raise _BadRequest("prompt: non-empty token id list")
    spec["prompt"] = prompt
    for key, cast in (("max_new_tokens", int), ("temperature", float),
                      ("priority", int), ("deadline_s", float)):
        if spec.get(key) is not None:
            try:
                spec[key] = cast(spec[key])
            except (TypeError, ValueError):
                raise _BadRequest(f"{key}: expected {cast.__name__}")
    return spec


class Server:
    """One engine behind one listening socket, all requests batched through
    the engine's shared driver task."""

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 8080):
        self.engine = engine
        self.host = host
        self.port = port
        self._server: asyncio.base_events.Server | None = None
        self._uid = 1 << 32   # below the engine's auto-uid range
        res = engine.resilience
        self.heartbeat_s = res.heartbeat_s
        # one shared backoff: while the engine stays overloaded, consecutive
        # rejections advance the attempt counter so the advertised
        # Retry-After values spread retrying clients out; the first accepted
        # request resets it
        self._backoff = rsl.Backoff(res.retry_after_base_s,
                                    res.retry_after_cap_s, seed=0)
        self._reject_streak = 0

    async def start(self):
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        return self._server.sockets[0].getsockname()[1]   # resolved port

    async def stop(self):
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    async def serve_forever(self):
        await self.start()
        async with self._server:
            await self._server.serve_forever()

    def _retry_after(self) -> dict:
        delay = self._backoff.delay(self._reject_streak)
        self._reject_streak += 1
        return {"Retry-After": str(max(1, math.ceil(delay)))}

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        try:
            req = await _read_request(reader)
            if req is None:
                writer.write(_http(
                    "400 Bad Request", "application/json",
                    _error_body("bad_request", "malformed HTTP request")))
            else:
                method, path, _, body = req
                try:
                    await self._route(writer, method, path, body)
                except (ConnectionResetError, BrokenPipeError,
                        asyncio.CancelledError):
                    raise
                except Exception:
                    # server fault: full traceback to the log, a generic
                    # body to the client (internals never leak over HTTP)
                    log.exception("unhandled error serving %s %s",
                                  method, path)
                    writer.write(_http(
                        "500 Internal Server Error", "application/json",
                        _error_body("server_error", "internal error; see "
                                    "server log")))
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError, asyncio.CancelledError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _route(self, writer, method: str, path: str, body: bytes):
        if method == "POST" and path == "/v1/generate":
            await self._generate(writer, body)
        elif method == "GET" and path == "/v1/metrics":
            payload = json.dumps(self._metrics()).encode()
            writer.write(_http("200 OK", "application/json", payload))
        elif method == "GET" and path == "/healthz":
            snap = self.engine.healthz()
            payload = json.dumps(snap).encode()
            if snap["state"] == "draining":
                writer.write(_http("503 Service Unavailable",
                                   "application/json", payload,
                                   extra=self._retry_after()))
            else:
                writer.write(_http("200 OK", "application/json", payload))
        elif method == "GET" and path == "/health":
            writer.write(_http("200 OK", "text/plain", b"ok"))
        else:
            writer.write(_http("404 Not Found", "application/json",
                               _error_body("not_found", path)))

    def _metrics(self) -> dict:
        eng = self.engine
        return {"throughput": eng.throughput(), "sla": eng.sla_report(),
                "health": eng.healthz(),
                "active": sum(1 for s in eng.slots if s.req is not None),
                "queued": len(eng.queue)}

    async def _generate(self, writer: asyncio.StreamWriter, body: bytes):
        try:
            spec = _parse_generate(body)
        except _BadRequest as exc:
            writer.write(_http("400 Bad Request", "application/json",
                               _error_body("bad_request", str(exc))))
            return
        if self.engine.health.state == "draining":
            writer.write(_http(
                "503 Service Unavailable", "application/json",
                _error_body("draining", "engine is draining; retry against "
                            "another replica"), extra=self._retry_after()))
            return
        if self.engine.overloaded():
            writer.write(_http(
                "429 Too Many Requests", "application/json",
                _error_body("overloaded", "queue above high-water mark; "
                            "honor Retry-After"), extra=self._retry_after()))
            return
        self._reject_streak = 0
        sampling = SamplingParams(
            max_new_tokens=int(spec.get("max_new_tokens", 32)),
            temperature=float(spec.get("temperature", 0.0)))
        self._uid += 1
        uid = self._uid
        stream = self.engine.generate(
            spec["prompt"], sampling, priority=int(spec.get("priority", 0)),
            prefix_len=spec.get("prefix_len"), uid=uid,
            deadline_s=spec.get("deadline_s"))
        writer.write(_http("200 OK", "text/event-stream", b"", stream=True))
        await writer.drain()
        pending: asyncio.Future | None = None
        try:
            it = stream.__aiter__()
            while True:
                if pending is None:
                    # NOT wait_for: cancelling __anext__ on a heartbeat
                    # timeout would kill the generator (and the request);
                    # the same future is re-awaited across heartbeats
                    pending = asyncio.ensure_future(it.__anext__())
                done_set, _ = await asyncio.wait({pending},
                                                 timeout=self.heartbeat_s)
                if not done_set:
                    writer.write(b": hb\n\n")   # SSE comment: liveness only
                    await writer.drain()
                    continue
                try:
                    tok = pending.result()
                except StopAsyncIteration:
                    pending = None
                    break
                pending = None
                writer.write(f"data: {json.dumps({'token': tok})}\n\n"
                             .encode())
                # drain per token: a disconnected client raises here, and
                # the stream's finally-cancel frees the pages right away
                await writer.drain()
        finally:
            if pending is not None:
                pending.cancel()
            await stream.aclose()
            req = next((r for r in reversed(self.engine.finished)
                        if r.uid == uid), None)
            done = {"done": True,
                    "stop_reason": getattr(req, "stop_reason", None)}
            path = getattr(req, "degrade_path", None)
            if path:
                done["degraded"] = list(path)
            try:
                writer.write(f"data: {json.dumps(done)}\n\n".encode())
                await writer.drain()
            except (ConnectionResetError, BrokenPipeError):
                pass


async def run_server(engine, host: str = "127.0.0.1", port: int = 8080):
    srv = Server(engine, host, port)
    await srv.serve_forever()
