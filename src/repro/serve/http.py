"""Async HTTP/SSE serving frontend — stdlib asyncio only, no new deps.

A deliberately small HTTP/1.1 surface over ``Engine.generate``:

  POST /v1/generate     body: {"prompt": [int token ids], "max_new_tokens",
                        "temperature", "priority", "prefix_len"} →
                        ``text/event-stream`` of one SSE event per token
                        (``data: {"token": t}``), terminated by
                        ``data: {"done": true, "stop_reason": ...}``.
  GET  /v1/metrics      JSON: throughput + SLA report (TTFT/TPOT
                        percentiles per priority class, preemption and
                        prefix-hit rates, queue depth, pool occupancy).
  GET  /health          200 ok.

Client disconnect mid-stream is detected on the next token write; the
generator's cleanup path cancels the request, which releases its pages and
resets its slot (including the speculative draft-cache row) immediately.
"""

from __future__ import annotations

import asyncio
import json

from repro.serve.config import SamplingParams

_MAX_BODY = 1 << 20


def _http(status: str, ctype: str, body: bytes, *, stream: bool = False):
    head = (f"HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n"
            + ("Cache-Control: no-store\r\nConnection: close\r\n\r\n" if stream
               else f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n"))
    return head.encode() + body


async def _read_request(reader: asyncio.StreamReader):
    """Parse request line + headers + (Content-Length) body; None on EOF
    or malformed input."""
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
        return None
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split(" ")
    if len(parts) < 2:
        return None
    method, path = parts[0].upper(), parts[1]
    headers = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    body = b""
    n = int(headers.get("content-length", 0) or 0)
    if n:
        if n > _MAX_BODY:
            return None
        body = await reader.readexactly(n)
    return method, path, headers, body


class Server:
    """One engine behind one listening socket, all requests batched through
    the engine's shared driver task."""

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 8080):
        self.engine = engine
        self.host = host
        self.port = port
        self._server: asyncio.base_events.Server | None = None
        self._uid = 1 << 32   # below the engine's auto-uid range

    async def start(self):
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        return self._server.sockets[0].getsockname()[1]   # resolved port

    async def stop(self):
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    async def serve_forever(self):
        await self.start()
        async with self._server:
            await self._server.serve_forever()

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        try:
            req = await _read_request(reader)
            if req is None:
                writer.write(_http("400 Bad Request", "text/plain", b"bad"))
            else:
                method, path, _, body = req
                if method == "POST" and path == "/v1/generate":
                    await self._generate(writer, body)
                elif method == "GET" and path == "/v1/metrics":
                    payload = json.dumps(self._metrics()).encode()
                    writer.write(_http("200 OK", "application/json", payload))
                elif method == "GET" and path == "/health":
                    writer.write(_http("200 OK", "text/plain", b"ok"))
                else:
                    writer.write(_http("404 Not Found", "text/plain", b"?"))
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError, asyncio.CancelledError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    def _metrics(self) -> dict:
        eng = self.engine
        return {"throughput": eng.throughput(), "sla": eng.sla_report(),
                "active": sum(1 for s in eng.slots if s.req is not None),
                "queued": len(eng.queue)}

    async def _generate(self, writer: asyncio.StreamWriter, body: bytes):
        try:
            spec = json.loads(body or b"{}")
            prompt = [int(t) for t in spec["prompt"]]
            assert prompt
        except (ValueError, KeyError, AssertionError, TypeError):
            writer.write(_http("400 Bad Request", "application/json",
                               b'{"error": "prompt: non-empty token id list"}'))
            return
        sampling = SamplingParams(
            max_new_tokens=int(spec.get("max_new_tokens", 32)),
            temperature=float(spec.get("temperature", 0.0)))
        self._uid += 1
        uid = self._uid
        stream = self.engine.generate(
            prompt, sampling, priority=int(spec.get("priority", 0)),
            prefix_len=spec.get("prefix_len"), uid=uid)
        writer.write(_http("200 OK", "text/event-stream", b"", stream=True))
        await writer.drain()
        try:
            async for tok in stream:
                writer.write(f"data: {json.dumps({'token': tok})}\n\n"
                             .encode())
                # drain per token: a disconnected client raises here, and
                # the stream's finally-cancel frees the pages right away
                await writer.drain()
        finally:
            await stream.aclose()
            req = next((r for r in reversed(self.engine.finished)
                        if r.uid == uid), None)
            done = {"done": True,
                    "stop_reason": getattr(req, "stop_reason", None)}
            try:
                writer.write(f"data: {json.dumps(done)}\n\n".encode())
                await writer.drain()
            except (ConnectionResetError, BrokenPipeError):
                pass


async def run_server(engine, host: str = "127.0.0.1", port: int = 8080):
    srv = Server(engine, host, port)
    await srv.serve_forever()
