from repro.serve.config import (AutotuneConfig, EngineConfig,  # noqa: F401
                                MemoryConfig, ResilienceConfig,
                                SamplingParams, SchedulerConfig,
                                SpeculativeConfig)
from repro.serve.engine import Engine, Request  # noqa: F401
from repro.serve.faults import Fault, FaultError, FaultPlan  # noqa: F401
from repro.serve.paged import PagedCache  # noqa: F401
from repro.serve.resilience import (DEGRADE_LADDER, Backoff,  # noqa: F401
                                    Guardrail, Health, Watchdog)
