from repro.serve.config import (AutotuneConfig, EngineConfig,  # noqa: F401
                                MemoryConfig, SamplingParams,
                                SchedulerConfig, SpeculativeConfig)
from repro.serve.engine import Engine, Request  # noqa: F401
from repro.serve.paged import PagedCache  # noqa: F401
