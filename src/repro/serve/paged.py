"""Paged block allocator for the serving caches (all four families).

Slot-static serving reserves ``max_len`` cache tokens per batch slot whether
a request uses 8 of them or 500, and stores a shared system prompt once per
slot.  This module sizes cache memory in *tokens* instead: a global pool of
fixed-size pages, per-request page tables, refcounted prefix sharing, and
the gather/scatter plumbing that feeds the existing jitted
``model.prefill_chunk`` unchanged.

Cache leaves split by their axes (``model.cache_axes()``):

  * **paged leaves** — leaves with a ``kv_seq`` axis of length ``max_len``
    (GQA K/V/pos + int8 scales, MLA latent/rope/pos + scales).  Pool
    storage is simply ``model.init_cache(n_pages, page_size)`` filtered to
    these leaves: the batch axis becomes the *page* axis, the sequence axis
    the within-page offset, so every storage format the model can allocate
    (float, int8 + scale rows) pages identically with zero per-format code.
  * **state leaves** — everything else: SSD / RG-LRU conv+state, and
    sliding-window rings (already O(window), not O(max_len)).  They stay
    slot-resident, and page-granular sharing is replaced by *snapshot
    slots*: a prefix entry stores a full copy of the row's state at the
    prefix boundary, restored on a prefix hit.

Per step the engine passes the jitted step an indices operand (the page
tables) plus the step's write plan; the wrapper

  1. resets freshly-allocated pages to the zero-page template (a recycled
     page carries the previous owner's ``pos`` values — stale entries
     would otherwise be attended as live keys),
  2. gathers each row's pages into a contiguous ``(B, max_len)`` view,
  3. runs the unchanged ``prefill_chunk`` on the view,
  4. scatters back only the pages inside each row's write window
     ``[steps, steps + n_tokens)``.

Shared prefix pages are never inside a write window (sharing is
page-aligned and a request's writes start after its shared prefix), so
copy-on-write degenerates to share-read-only + allocate-fresh-for-writes:
no page is ever copied, and step (4) cannot corrupt a shared page.
Speculative rounds ride the same wrapper: the round's rollback rewinds the
*view* bit-exactly before the scatter, and the engine frees any page the
round allocated beyond the committed length.

Host-side accounting (``PagePool``, ``PrefixIndex``) is plain numpy /
Python — allocation decisions happen at schedule time where the engine
already runs per-slot Python, and determinism falls out for free.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def _is_axes(x) -> bool:
    return x is None or (isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x))


# ---------------------------------------------------------------------------
# Host-side page accounting.
# ---------------------------------------------------------------------------


class PagePool:
    """Free list + per-page refcounts.

    Page 0 is the reserved *zero page* (pristine template content): page
    tables point unallocated logical pages at it, so a gathered view's tail
    always reads pos=-1 / zeros.  It is never allocated or freed.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("page pool needs >= 2 pages (page 0 is the "
                             "reserved zero page)")
        self.n_pages = n_pages
        self.ref = np.zeros((n_pages,), np.int32)
        self._free = list(range(n_pages - 1, 0, -1))   # LIFO, page 0 reserved

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self) -> int | None:
        """Pop a free page with refcount 1, or None if the pool is dry."""
        if not self._free:
            return None
        p = self._free.pop()
        assert self.ref[p] == 0, f"free list held referenced page {p}"
        self.ref[p] = 1
        return p

    def ref_inc(self, p: int):
        assert p != 0 and self.ref[p] > 0, f"ref_inc of unowned page {p}"
        self.ref[p] += 1

    def deref(self, p: int):
        assert p != 0, "deref of the reserved zero page"
        assert self.ref[p] > 0, f"double free of page {p}"
        self.ref[p] -= 1
        if self.ref[p] == 0:
            self._free.append(p)


@dataclasses.dataclass
class PrefixEntry:
    length: int            # tokens covered (page-aligned)
    pages: list            # physical pages holding the prefix (KV leaves)
    snap: int | None       # snapshot slot holding the state leaves, if any
    last_use: int          # LRU clock


class PrefixIndex:
    """token-tuple-keyed prefix cache: exact (collision-free) chain keys."""

    def __init__(self):
        self.entries: dict[tuple, PrefixEntry] = {}
        self._clock = 0

    def tick(self) -> int:
        self._clock += 1
        return self._clock

    def lookup(self, tokens: list[int], page_size: int,
               max_tokens: int) -> PrefixEntry | None:
        """Longest registered page-aligned prefix of ``tokens`` covering at
        most ``max_tokens`` (serving always recomputes ≥1 prompt token —
        the sampler needs the last token's logits)."""
        j = min(len(tokens), max_tokens) // page_size
        while j > 0:
            e = self.entries.get(tuple(tokens[: j * page_size]))
            if e is not None:
                e.last_use = self.tick()
                return e
            j -= 1
        return None

    def lru(self) -> tuple | None:
        if not self.entries:
            return None
        return min(self.entries, key=lambda k: self.entries[k].last_use)


# ---------------------------------------------------------------------------
# Device-side paged cache.
# ---------------------------------------------------------------------------


class PagedCache:
    """Pool storage + jitted gather/scatter around ``prefill_chunk``.

    The engine owns policy (scheduling, preemption victims, admission);
    this class owns mechanics: leaf classification, page/snapshot pools,
    page tables, the prefix index, and the jitted step wrappers.
    """

    def __init__(self, model, slots: int, max_len: int, page_size: int,
                 n_pages: int, snap_slots: int, prefix_sharing: bool = True):
        if max_len % page_size != 0:
            raise ValueError(f"max_len={max_len} must be a multiple of "
                             f"page_size={page_size}")
        self.model = model
        self.B = slots
        self.max_len = max_len
        self.ps = page_size
        self.n_pp = max_len // page_size
        self.sharing = prefix_sharing

        template = model.init_cache(slots, max_len)
        leaves, self.treedef = jax.tree.flatten(template)
        axes = model.cache_axes()
        bax_tree = jax.tree.map(lambda ax: ax.index("batch"), axes,
                                is_leaf=_is_axes)
        seq_tree = jax.tree.map(
            lambda ax: ax.index("kv_seq") if "kv_seq" in ax else -1, axes,
            is_leaf=_is_axes)
        self.bax, _ = jax.tree.flatten(bax_tree)
        seqax, _ = jax.tree.flatten(seq_tree)
        axes_flat, _ = jax.tree.flatten(axes, is_leaf=_is_axes)
        self.paged_mask: list[bool] = []
        for leaf, b, s in zip(leaves, self.bax, seqax):
            paged = s >= 0 and leaf.shape[s] == max_len
            if paged:
                assert s == b + 1, "paged leaves need kv_seq right after batch"
            self.paged_mask.append(paged)
        self.has_paged = any(self.paged_mask)
        self.has_state = not all(self.paged_mask)

        def _split(ls, mask_val):
            return [l for l, m in zip(ls, self.paged_mask) if m is mask_val]

        # pool storage: init_cache with batch=pages, max_len=page_size —
        # every storage format the model allocates pages identically
        self.n_pages = n_pages if self.has_paged else 2
        self.pool = _split(
            jax.tree.flatten(model.init_cache(self.n_pages, page_size))[0],
            True)
        self._page_tmpl = _split(
            jax.tree.flatten(model.init_cache(1, page_size))[0], True)
        self.static = _split(leaves, False)
        self._static_tmpl = list(self.static)
        self._pbax = _split(self.bax, True)
        self._sbax = _split(self.bax, False)
        self._paxes = _split(axes_flat, True)
        self._saxes = _split(axes_flat, False)

        # recurrent/ring state snapshots for prefix sharing
        self.n_snap = snap_slots if (self.has_state and prefix_sharing) else 0
        self.snap = (_split(jax.tree.flatten(
            model.init_cache(max(self.n_snap, 1), max_len))[0], False)
            if self.n_snap else [])
        self._snap_free = list(range(self.n_snap - 1, -1, -1))

        self.pages = PagePool(self.n_pages)
        self.tables = np.zeros((slots, self.n_pp), np.int32)  # 0 = unallocated
        self.prefix = PrefixIndex()

        self._jit_slot_reset = jax.jit(self._slot_reset_impl)
        self._jit_snap_save = jax.jit(self._snap_save_impl)
        self._jit_snap_restore = jax.jit(self._snap_restore_impl)

    def shard(self, parallel) -> None:
        """Lay the pool / static / snapshot leaves out on the serving mesh.

        Each leaf reuses its family's cache axes (``model.cache_axes()``) —
        so int8 scale rows shard alongside their codes — with one rewrite:
        the *page* axis (the leaf position the axes call "batch") and the
        snapshot-slot axis replicate, because pages and snapshots are pooled
        resources every data shard must reach by global index.  Slot-static
        leaves keep the batch→data sharding; the in-page ``kv_seq`` axis and
        head axes shard over "model" per the standard rules."""
        from jax.sharding import NamedSharding
        from repro.launch.sharding import partition_spec
        if not parallel.active:
            return

        def put(leaves, axes_list, *, pooled):
            out = []
            for leaf, ax in zip(leaves, axes_list):
                if pooled:
                    ax = tuple(None if a == "batch" else a for a in ax)
                spec = partition_spec(ax, leaf.shape, parallel)
                out.append(jax.device_put(
                    leaf, NamedSharding(parallel.mesh, spec)))
            return out

        self.pool = put(self.pool, self._paxes, pooled=True)
        self._page_tmpl = put(self._page_tmpl, self._paxes, pooled=True)
        self.static = put(self.static, self._saxes, pooled=False)
        self._static_tmpl = put(self._static_tmpl, self._saxes, pooled=False)
        if self.snap:
            self.snap = put(self.snap, self._saxes, pooled=True)

    # -- jitted mechanics ----------------------------------------------------

    def _reset_fresh(self, pool, fresh):
        """Reset freshly-allocated pages to the zero-page template (recycled
        pages carry the previous owner's pos/content)."""
        out = []
        for leaf, tmpl, b in zip(pool, self._page_tmpl, self._pbax):
            idx = (slice(None),) * b + (fresh,)
            out.append(leaf.at[idx].set(tmpl, mode="drop"))
        return out

    def _gather(self, pool, table):
        """pool pages → contiguous (B, max_len) view per paged leaf."""
        B, n_pp = table.shape
        out = []
        for leaf, b in zip(pool, self._pbax):
            g = jnp.take(leaf, table.reshape(-1), axis=b)
            sh = g.shape[:b] + (B, n_pp * self.ps) + g.shape[b + 2:]
            out.append(g.reshape(sh))
        return out

    def _scatter(self, pool, view, rows, lps, phys):
        """Write the (row, logical page) → physical page triples back.
        Padding triples point phys at n_pages (dropped)."""
        idx = rows * self.n_pp + lps                      # (M,)
        out = []
        for leaf, v, b in zip(pool, view, self._pbax):
            v2 = v.reshape(v.shape[:b] + (self.B * self.n_pp, self.ps)
                           + v.shape[b + 2:])
            src = jnp.take(v2, idx, axis=b)               # (..., M, ps, ...)
            out.append(leaf.at[(slice(None),) * b + (phys,)].set(
                src, mode="drop"))
        return out

    def _merge(self, paged_leaves, static_leaves):
        pi, si, out = iter(paged_leaves), iter(static_leaves), []
        for m in self.paged_mask:
            out.append(next(pi) if m else next(si))
        return out

    def _split_new(self, leaves):
        paged = [l for l, m in zip(leaves, self.paged_mask) if m]
        static = [l for l, m in zip(leaves, self.paged_mask) if not m]
        return paged, static

    def make_step(self):
        """Jitted paged step: reset-fresh → gather → prefill_chunk →
        scatter-write-window.  jit keys compiled variants by the bucketed
        (chunk, fresh, triples) shapes."""
        model, treedef = self.model, self.treedef

        def step(params, pool, static, table, fresh, rows, lps, phys,
                 tokens, steps, n_tokens):
            pool = self._reset_fresh(list(pool), fresh)
            view = self._gather(pool, table)
            cache = jax.tree.unflatten(treedef, self._merge(view, static))
            logits, new_cache = model.prefill_chunk(params, cache, tokens,
                                                    steps, n_tokens)
            new_paged, new_static = self._split_new(
                jax.tree.flatten(new_cache)[0])
            new_pool = self._scatter(pool, new_paged, rows, lps, phys)
            return logits, tuple(new_pool), tuple(new_static)

        return jax.jit(step)

    def make_spec_step(self, inner):
        """Wrap a fused speculative round (see ``Engine._make_spec_round``)
        with the same reset/gather/scatter plumbing.  The round's rollback
        rewinds the *view* bit-exactly, so scattering the full k+1-token
        write window writes rejected positions back with their pre-round
        (or zero-template) bytes."""
        treedef = self.treedef

        def step(params, dp, pool, static, dcache, table, fresh, rows, lps,
                 phys, cur, steps, live, budget):
            pool = self._reset_fresh(list(pool), fresh)
            view = self._gather(pool, table)
            cache = jax.tree.unflatten(treedef, self._merge(view, static))
            cache, dcache, draft_toks, greedy, n_acc, n_comm, ok = inner(
                params, dp, cache, dcache, cur, steps, live, budget)
            new_paged, new_static = self._split_new(
                jax.tree.flatten(cache)[0])
            new_pool = self._scatter(pool, new_paged, rows, lps, phys)
            return (tuple(new_pool), tuple(new_static), dcache, draft_toks,
                    greedy, n_acc, n_comm, ok)

        return jax.jit(step)

    def _slot_reset_impl(self, static, b):
        out = []
        for leaf, tmpl, bx in zip(static, self._static_tmpl, self._sbax):
            idx = (slice(None),) * bx + (b,)
            out.append(leaf.at[idx].set(tmpl[idx]))
        return tuple(out)

    def _snap_save_impl(self, snap, static, dst, b):
        out = []
        for s_leaf, leaf, bx in zip(snap, static, self._sbax):
            idx_d = (slice(None),) * bx + (dst,)
            idx_s = (slice(None),) * bx + (b,)
            out.append(s_leaf.at[idx_d].set(leaf[idx_s]))
        return tuple(out)

    def _snap_restore_impl(self, static, snap, src, b):
        out = []
        for leaf, s_leaf, bx in zip(static, snap, self._sbax):
            idx_d = (slice(None),) * bx + (b,)
            idx_s = (slice(None),) * bx + (src,)
            out.append(leaf.at[idx_d].set(s_leaf[idx_s]))
        return tuple(out)

    # -- host-side bookkeeping ----------------------------------------------

    def reset_slot(self, b: int):
        """Reset slot b's state-leaf rows from the pristine template (pages
        need no reset here — they are freed, and recycled pages reset on
        allocation)."""
        if self.static:
            self.static = list(self._jit_slot_reset(
                tuple(self.static), jnp.int32(b)))

    def free_slot(self, b: int):
        """Release every page slot b's table references (shared prefix pages
        survive through their index/entry refcounts)."""
        for lp in range(self.n_pp):
            p = int(self.tables[b, lp])
            if p:
                self.pages.deref(p)
                self.tables[b, lp] = 0

    def slot_pages(self, b: int) -> int:
        return int(np.count_nonzero(self.tables[b]))

    def plan_writes(self, b: int, pos: int, n: int):
        """Allocate pages covering row b's write window [pos, pos+n).

        Returns ``(fresh, triples)`` — fresh page ids to zero-reset and
        (row, lp, phys) scatter triples — or None if the pool ran dry
        (allocations made so far are rolled back; the engine evicts or
        preempts and retries)."""
        if not self.has_paged or n <= 0:
            return [], []
        lp0, lp1 = pos // self.ps, (pos + n - 1) // self.ps
        fresh, triples = [], []
        for lp in range(lp0, lp1 + 1):
            p = int(self.tables[b, lp])
            if p == 0:
                p = self.pages.alloc()
                if p is None:
                    for fp in fresh:           # roll back this plan
                        self.pages.deref(fp)
                        self.tables[b, np.where(self.tables[b] == fp)[0]] = 0
                    return None
                self.tables[b, lp] = p
                fresh.append(p)
            triples.append((b, lp, p))
        return fresh, triples

    def max_take(self, b: int, pos: int) -> int:
        """Largest n for which ``plan_writes(b, pos, n)`` would succeed
        right now (existing pages + free pool)."""
        if not self.has_paged:
            return self.max_len
        take = 0
        budget = self.pages.n_free
        lp = pos // self.ps
        off = pos
        while lp < self.n_pp:
            if int(self.tables[b, lp]) == 0:
                if budget == 0:
                    break
                budget -= 1
            take += (lp + 1) * self.ps - off
            off = (lp + 1) * self.ps
            lp += 1
        return take

    def free_beyond(self, b: int, pos: int):
        """Free pages wholly beyond ``pos`` tokens (speculative rollback:
        pages allocated for a round's write window but left uncommitted)."""
        first_unused = (pos + self.ps - 1) // self.ps
        for lp in range(first_unused, self.n_pp):
            p = int(self.tables[b, lp])
            if p:
                self.pages.deref(p)
                self.tables[b, lp] = 0

    # -- prefix sharing -------------------------------------------------------

    def prefix_lookup(self, tokens: list[int]) -> PrefixEntry | None:
        if not self.sharing:
            return None
        # always leave ≥1 token to recompute: the sampler needs the last
        # prompt token's logits, which the prefix cache does not store
        return self.prefix.lookup(tokens, self.ps, len(tokens) - 1)

    def prefix_admit(self, b: int, entry: PrefixEntry):
        """Point slot b's table at a shared prefix and restore its state
        snapshot.  Caller sets slot.pos = entry.length."""
        for lp, p in enumerate(entry.pages):
            assert int(self.tables[b, lp]) == 0
            self.pages.ref_inc(p)
            self.tables[b, lp] = p
        if entry.snap is not None:
            self.static = list(self._jit_snap_restore(
                tuple(self.static), tuple(self.snap),
                jnp.int32(entry.snap), jnp.int32(b)))

    def register_prefix(self, b: int, tokens: list[int], length: int) -> bool:
        """Register slot b's first ``length`` (page-aligned) tokens.

        KV pages are shared by reference (the entry holds a refcount on
        each); state leaves are copied into a snapshot slot.  Returns False
        when a needed snapshot slot cannot be found even after evicting
        unreferenced entries."""
        if not self.sharing or length <= 0 or length % self.ps:
            return False
        key = tuple(tokens[:length])
        if key in self.prefix.entries:
            return True
        snap = None
        if self.has_state:
            while not self._snap_free:
                if not self.evict_one():
                    return False
            snap = self._snap_free.pop()
            self.snap = list(self._jit_snap_save(
                tuple(self.snap), tuple(self.static),
                jnp.int32(snap), jnp.int32(b)))
        pages = [int(self.tables[b, lp]) for lp in range(length // self.ps)]
        assert all(pages) or not self.has_paged
        for p in pages:
            if p:
                self.pages.ref_inc(p)
        self.prefix.entries[key] = PrefixEntry(
            length=length, pages=[p for p in pages if p], snap=snap,
            last_use=self.prefix.tick())
        return True

    def register_levels(self, b: int, tokens: list[int], length: int):
        """Register every page-aligned prefix level up to ``length`` (pure-KV
        models: entries share page refs, so a later request matching any
        shared depth hits; state models register single levels via
        ``register_prefix`` — each level would cost a snapshot slot)."""
        for j in range(1, length // self.ps + 1):
            self.register_prefix(b, tokens, j * self.ps)

    def evict_one(self, require_free: bool = False) -> bool:
        """Drop the least-recently-used prefix entry, releasing its page
        refs and snapshot slot.  Pages still referenced by a live request
        stay resident; fully-unreferenced ones return to the free list.

        ``require_free``: only evict an entry whose release returns at
        least one page to the free list (some page solely owned by the
        entry).  Page-pressure escalation uses this so it cannot wipe a
        hot shared prefix — still pinned by live page tables — without
        gaining any memory for the allocator."""
        order = sorted(self.prefix.entries,
                       key=lambda k: self.prefix.entries[k].last_use)
        for key in order:
            e = self.prefix.entries[key]
            if require_free and not any(
                    int(self.pages.ref[p]) == 1 for p in e.pages):
                continue
            self.prefix.entries.pop(key)
            for p in e.pages:
                self.pages.deref(p)
            if e.snap is not None:
                self._snap_free.append(e.snap)
            return True
        return False

    # -- accounting / invariants ----------------------------------------------

    def nbytes(self) -> int:
        from repro import quant as qt
        return (qt.tree_nbytes(self.pool) + qt.tree_nbytes(self.static)
                + qt.tree_nbytes(self.snap))

    def pool_tokens(self) -> int:
        return (self.n_pages - 1) * self.ps if self.has_paged else 0

    def occupancy(self) -> dict:
        """Pool residency snapshot for health/admission reporting: usable
        pages (the zero page is reserved), free pages, and the occupied
        fraction.  Reads only host-side counters — safe to call from the
        health endpoint while a step is in flight."""
        if not self.has_paged:
            return {"pages": 0, "pages_free": 0, "occupancy": 0.0}
        usable = self.n_pages - 1
        free = self.pages.n_free
        return {"pages": usable, "pages_free": free,
                "occupancy": (usable - free) / usable if usable else 0.0}

    def audit(self):
        """Invariant check (tests call this after every mutation batch):
        per-page refcounts equal table references + prefix-entry references;
        the free list is exactly the unreferenced pages, duplicate-free;
        snapshot slots are consistently owned."""
        refs = np.zeros((self.n_pages,), np.int32)
        for b in range(self.B):
            for lp in range(self.n_pp):
                p = int(self.tables[b, lp])
                if p:
                    refs[p] += 1
        for e in self.prefix.entries.values():
            for p in e.pages:
                refs[p] += 1
        assert refs[0] == 0, "zero page must never be referenced by tables"
        np.testing.assert_array_equal(refs, self.pages.ref)
        free = self.pages._free
        assert len(free) == len(set(free)), "duplicate pages in free list"
        assert 0 not in free, "zero page on the free list"
        expect_free = {p for p in range(1, self.n_pages) if refs[p] == 0}
        assert set(free) == expect_free, (set(free), expect_free)
        snaps = [e.snap for e in self.prefix.entries.values()
                 if e.snap is not None]
        assert len(snaps) == len(set(snaps)), "snapshot slot double-owned"
        assert set(snaps).isdisjoint(self._snap_free)
        assert set(snaps) | set(self._snap_free) <= set(range(self.n_snap))
