"""Continuous-batching inference engine with chunked prefill.

A fixed pool of B slots advances through one jitted ``prefill_chunk`` per
iteration.  Each iteration the scheduler packs a *mixed* batch under a token
budget (vLLM-style chunked prefill): slots still ingesting their prompt
contribute up to ``chunk_size`` prompt tokens, slots in generation contribute
exactly one token — so a 512-token prompt costs ceil(512/C) steps instead of
512, while decodes keep flowing in the same batches.

One model call serves every row shape: ``prefill_chunk(params, cache,
tokens (B, C), steps (B,), n_tokens (B,))`` writes each slot's KV/state cache
at its own offset and masks the ragged tail columns.  The per-iteration chunk
width C is bucketed to powers of two, so the jitted step function (shared
across engines via ``step_fn`` — jit's trace cache keys it by chunk shape)
compiles O(log chunk_size) variants total.

A finished slot is recycled immediately for the next queued request — no
batch drain.  Sampling: greedy or temperature.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant as qt


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False  # ran out of cache capacity (max_len) early


@dataclasses.dataclass
class _Slot:
    req: Request | None = None
    pos: int = 0            # next absolute position to write
    to_feed: deque = dataclasses.field(default_factory=deque)  # prompt left


def _bucket(n: int) -> int:
    """Round a chunk width up to a power of two (bounds jit retraces)."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _blast_shapes(tree) -> list[tuple[int, int, int, int]]:
    """(d_out, d_in, b, r) for every BLAST linear in a params tree — reads
    the *array* shapes, so truncated draft params report their r'."""
    out = []
    if isinstance(tree, dict):
        if set(tree) - {"bias"} == {"U", "S", "V"}:
            u, v = tree["U"], tree["V"]
            # trailing 3 axes are (b, p, r) even under cycle/expert stacking
            b, p, r = (int(d) for d in u.shape[-3:])
            out.append((b * p, b * int(v.shape[-2]), b, r))
            return out
        for v in tree.values():
            out += _blast_shapes(v)
    return out


class Engine:
    def __init__(self, model, params, *, batch_slots: int = 4,
                 max_len: int = 512, seed: int = 0, chunk_size: int = 32,
                 token_budget: int | None = None, step_fn=None, quant=None,
                 autotune: bool = False, autotune_cache: str | None = None,
                 speculative: int = 0, draft_rank_frac: float = 0.5,
                 prestack: bool = True):
        """``chunk_size``: max prompt tokens one slot ingests per iteration.
        ``token_budget``: max total tokens per iteration across all slots
        (default: every slot may prefill a full chunk).  ``step_fn``:
        optionally share one ``jax.jit(model.prefill_chunk)`` across engines
        — jit's trace cache keys compiled steps by chunk shape, so engines
        with the same slot count reuse each other's compiles.

        ``autotune``: warm the BLAST kernel tiling cache at engine build —
        every structured linear the model dispatches is timed at this
        engine's decode width (B·1 rows) and full-chunk prefill width, and
        the winning (block_t, block_r) configs persist to
        ``autotune_cache`` (JSON; see kernels/autotune.py).  The cache is
        consulted by every ``kernels/ops`` BLAST wrapper at trace time —
        i.e. the per-device shard_map/TPU execution path and kernel
        benchmarks; the default GSPMD serving step lowers through the XLA
        einsum apply paths (repo convention) and is unaffected.  Off by
        default: tiling falls back to ``pick_blast_blocks`` and numerics
        are identical either way.

        Quantize-at-load: when the model config's ``quant.weights`` knob is
        set (or a ``quant: QuantConfig`` override is passed) and ``params``
        are still float, they convert to per-block QArrays here, once — the
        jitted step then runs the fused-dequant apply path and the resident
        weight bytes drop 2× (int8) / 4× (int4).  ``quant.cache`` must be
        set on the *model's* config (``init_cache`` allocates int8 + scales
        from it); an override requesting cache quantization the model was
        not built with raises.

        Self-speculative decoding: ``speculative=k > 0`` drafts k tokens
        per decode round with a rank-truncated view of the SAME weights
        (``draft_rank_frac`` of the pooled rank budget; see
        ``LM.draft_plan``/``truncate_params``) and verifies them in one
        all-logits ``prefill_chunk`` of the full model.  Acceptance is
        exact greedy prefix match, so greedy outputs are token-identical to
        plain decode; rejected suffixes are rolled back bit-exactly
        (``LM.rollback_cache``).  Rounds run only on iterations where every
        scheduled slot is decoding greedily; prefill chunks and
        temperature>0 sampling take the plain path (the draft cache is kept
        in sync by replaying those chunks through the draft model).

        ``prestack=True`` pre-stacks every grouped projection bundle once
        here instead of per step (``LM.prestack_params``)."""
        self.model = model
        qcfg = quant if quant is not None else getattr(model.cfg, "quant", None)
        if (qcfg is not None and qcfg.cache != "none"
                and not model.cfg.cache_quant):
            # cache shapes are baked into the model at construction
            raise ValueError(
                "quant.cache is a model-construction knob: build the model "
                "with ArchConfig.quant (init_cache allocates int8 + scales "
                "from it); the Engine quant= override only covers weights")
        if (qcfg is not None and qcfg.weight_bits is not None
                and not qt.tree_is_quantized(params)):
            params = jax.jit(
                lambda p: model.quantize_params(p, qcfg))(params)
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.chunk = max(1, int(chunk_size))
        self.token_budget = (batch_slots * self.chunk if token_budget is None
                             else max(1, int(token_budget)))
        self.cache = model.init_cache(batch_slots, max_len)
        self._template = self.cache  # pristine zero cache (reset source)
        # per-leaf batch-axis position (stacked layer caches carry a leading
        # "layers" axis, so batch is NOT uniformly axis 0)
        axes = model.cache_axes()
        is_axes = lambda x: x is None or (isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
        self._batch_axis = jax.tree.map(
            lambda ax: ax.index("batch"), axes, is_leaf=is_axes)
        self.slots = [_Slot() for _ in range(batch_slots)]
        self._rr = 0  # round-robin start for budget allocation
        self.queue: deque[Request] = deque()
        self.key = jax.random.PRNGKey(seed)
        self._step = step_fn if step_fn is not None else jax.jit(
            model.prefill_chunk)
        self.stats = {"steps": 0, "prefill_tokens": 0, "decode_tokens": 0,
                      "prefill_time": 0.0, "decode_time": 0.0,
                      # per-step wall times: all steps + pure-decode steps
                      # (benchmarks reduce these to latency percentiles)
                      "step_s": [], "decode_step_s": [],
                      # speculative rounds: drafted/accepted counts per round
                      "spec_rounds": 0, "spec_drafted": 0, "spec_accepted": 0,
                      "spec_emitted": 0}
        self.spec_k = max(0, int(speculative))
        self.draft_rank_frac = float(draft_rank_frac)
        if self.spec_k:
            needed = ("draft_plan", "truncate_params", "rollback_cache")
            if not all(hasattr(model, a) for a in needed):
                raise ValueError(
                    "speculative decoding needs a model with "
                    f"{needed} (repro.models.transformer.LM)")
            self.draft_plan = model.draft_plan(self.params,
                                               self.draft_rank_frac)
            plan = self.draft_plan
            self.draft_params = jax.jit(
                lambda p: model.truncate_params(p, plan))(self.params)
            if prestack and hasattr(model, "prestack_params"):
                self.draft_params = jax.jit(model.prestack_params)(
                    self.draft_params)
            self.draft_cache = model.init_cache(batch_slots, max_len)
            self._draft_template = self.draft_cache
            self._spec_round = jax.jit(self._make_spec_round())
        if prestack and hasattr(model, "prestack_params"):
            self.params = jax.jit(model.prestack_params)(self.params)
        if autotune:
            self._warm_autotune(qcfg, autotune_cache)

    def _make_spec_round(self):
        """Build the fused draft-verify round: ONE jitted dispatch per round.

        Drafting k tokens with host-side control costs k device syncs plus
        k+3 dispatches — more wall time than the k+1 plain steps it
        replaces.  Fusing the draft scan, the all-logits verify, the greedy
        accept, the cache rollback and the draft-cache resync into a single
        jitted function leaves one dispatch and one host transfer (the
        drafted/accepted token ids) per round.
        """
        model, k = self.model, self.spec_k
        Cv = _bucket(k + 1)

        def spec_round(p, dp, cache, dcache, cur, steps, live, budget):
            B = cur.shape[0]
            # -- draft: k single-token steps on a throwaway dcache copy
            def body(carry, i):
                c, tok = carry
                lg, c = model.prefill_chunk(dp, c, tok[:, None], steps + i,
                                            live)
                nxt = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)
                return (c, nxt), nxt
            _, seq = jax.lax.scan(body, (dcache, cur),
                                  jnp.arange(k, dtype=jnp.int32))
            draft_toks = seq.T                                     # (B, k)
            # -- verify: one full-model all-logits chunk over [t0, d_1..d_k]
            pad = jnp.zeros((B, Cv - k - 1), jnp.int32)
            vt = jnp.concatenate([cur[:, None], draft_toks, pad], axis=1)
            lg, new_cache = model.prefill_chunk(
                p, cache, vt, steps, live * (k + 1),
                all_logits=True, collect_states=True)
            greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)     # (B, Cv)
            # -- accept: longest greedy-matching draft prefix (+ bonus)
            match = draft_toks == greedy[:, :k]
            n_acc = jnp.where(match.all(axis=1), k,
                              jnp.argmax(~match, axis=1)).astype(jnp.int32)
            n_comm = jnp.minimum(n_acc + 1, budget) * live
            # -- commit: bit-exact rewind + one ragged draft resync chunk
            cache = model.rollback_cache(cache, new_cache, steps, n_comm)
            _, dcache = model.prefill_chunk(dp, dcache, vt, steps, n_comm)
            return cache, dcache, draft_toks, greedy, n_acc, n_comm

        return spec_round

    def _warm_autotune(self, qcfg, cache_path: str | None):
        """Tune the fused-kernel tiling for every unique BLAST shape this
        model dispatches, at the decode (B rows) and full-prefill-chunk
        widths this engine will actually run, then persist the cache."""
        from repro.kernels import autotune as at

        at.enable(cache_path)
        kind = {None: "float", 8: "int8", 4: "int4"}[
            qcfg.weight_bits if qcfg is not None else None]
        dtype = jnp.dtype(self.model.cfg.compute_dtype)
        widths = sorted({self.B, self.B * _bucket(self.chunk)})
        shapes = []
        for spec in getattr(self.model, "linear_specs", list)():
            if spec.kind == "blast":
                shapes.append((spec.d_out, spec.d_in, spec.meta["b"],
                               spec.meta["r"]))
        if self.spec_k:
            # the draft model dispatches the same blocked shapes at the
            # truncated ranks — warm those too (draft steps run at decode
            # width and at the verify chunk width)
            shapes += _blast_shapes(self.draft_params)
        seen = set()
        for d_out, d_in, b, r in shapes:
            for T in widths:
                key = (T, d_out, d_in, b, r)
                if key in seen:
                    continue
                seen.add(key)
                at.tune_blast(T, d_out, d_in, b, r, dtype=dtype,
                              kind=kind, reps=1)
        at.save()

    # -- public ---------------------------------------------------------------

    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(f"request {req.uid}: empty prompt (generation "
                             "needs at least one conditioning token)")
        self.queue.append(req)

    def run(self, max_iters: int = 10_000) -> list[Request]:
        """Drive until queue + slots drain.  Returns completed requests."""
        finished: list[Request] = []
        for _ in range(max_iters):
            self._admit()
            if not any(s.req for s in self.slots):
                if not self.queue:
                    break
                continue
            if self.spec_k and self._spec_eligible():
                self._advance_spec(finished)
            else:
                self._advance(finished)
        return finished

    def _spec_eligible(self) -> bool:
        """Speculative rounds run only when every active slot is in greedy
        decode (prompt fully ingested, ≥1 sampled token).  Prefill chunks
        and temperature sampling use the plain path — exactness of the
        accept rule needs argmax on both sides."""
        active = [s for s in self.slots if s.req is not None]
        return bool(active) and all(
            not s.to_feed and s.req.output and s.req.temperature == 0
            for s in active)

    def throughput(self) -> dict:
        """Prefill / decode tokens-per-second split from engine stats."""
        s = self.stats
        out = {
            "steps": s["steps"],
            "prefill_tok_s": (s["prefill_tokens"] / s["prefill_time"]
                              if s["prefill_time"] else 0.0),
            "decode_tok_s": (s["decode_tokens"] / s["decode_time"]
                             if s["decode_time"] else 0.0),
        }
        if self.spec_k:
            out["spec_rounds"] = s["spec_rounds"]
            out["acceptance_rate"] = (s["spec_accepted"] / s["spec_drafted"]
                                      if s["spec_drafted"] else 0.0)
            out["tokens_per_round"] = (s["spec_emitted"] / s["spec_rounds"]
                                       if s["spec_rounds"] else 0.0)
        return out

    # -- internals --------------------------------------------------------------

    def _reset_slot(self, b: int):
        def reset(bax, c, t):
            idx = (slice(None),) * bax + (b,)
            return c.at[idx].set(t[idx])
        self.cache = jax.tree.map(reset, self._batch_axis, self.cache,
                                  self._template)
        if self.spec_k:
            self.draft_cache = jax.tree.map(
                reset, self._batch_axis, self.draft_cache,
                self._draft_template)

    def _admit(self):
        for b, slot in enumerate(self.slots):
            if slot.req is None and self.queue:
                req = self.queue.popleft()
                self._reset_slot(b)
                slot.req = req
                slot.pos = 0
                slot.to_feed = deque(req.prompt)

    def _schedule(self) -> np.ndarray:
        """Token-budget pass: decodes first (1 token each, latency), then
        prefills split the remaining budget into ≤chunk_size chunks.  Slots
        are visited in round-robin order so a budget tighter than the active
        slot count rotates starvation instead of pinning it to high slots."""
        n = np.zeros((self.B,), np.int32)
        budget = self.token_budget
        order = [(b + self._rr) % self.B for b in range(self.B)]
        self._rr = (self._rr + 1) % self.B
        for b in order:
            slot = self.slots[b]
            if slot.req is not None and not slot.to_feed and budget > 0:
                n[b] = 1
                budget -= 1
        for b in order:
            slot = self.slots[b]
            if slot.req is None or not slot.to_feed:
                continue
            room = self.max_len - 1 - slot.pos  # leave headroom to sample
            take = min(len(slot.to_feed), self.chunk, budget, max(room, 0))
            n[b] = take
            budget -= take
        return n

    def _advance(self, finished: list[Request]):
        n = self._schedule()
        if not n.any():  # every active slot is out of cache headroom
            for b, slot in enumerate(self.slots):
                if slot.req is not None:
                    slot.req.done = True
                    slot.req.truncated = True  # prompt didn't fit max_len
                    finished.append(slot.req)
                    slot.req = None
            return
        C = _bucket(int(n.max()))
        tokens = np.zeros((self.B, C), np.int32)
        steps = np.zeros((self.B,), np.int32)
        sampling = [False] * self.B
        prompt_toks = 0
        decode_toks = 0
        for b, slot in enumerate(self.slots):
            if slot.req is None or n[b] == 0:
                continue
            steps[b] = slot.pos
            if slot.to_feed:
                prompt_toks += int(n[b])
                for i in range(n[b]):
                    tokens[b, i] = slot.to_feed.popleft()
                sampling[b] = len(slot.to_feed) == 0  # chunk holds prompt end
            else:
                decode_toks += 1
                tokens[b, 0] = slot.req.output[-1]
                sampling[b] = True
        t0 = time.perf_counter()
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(steps),
            jnp.asarray(n))
        if self.spec_k:
            # keep the draft cache in sync through prefill / non-greedy
            # iterations: replay the same chunk through the draft model
            _, self.draft_cache = self._step(
                self.draft_params, self.draft_cache, jnp.asarray(tokens),
                jnp.asarray(steps), jnp.asarray(n))
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.stats["steps"] += 1
        self.stats["prefill_tokens"] += prompt_toks
        self.stats["decode_tokens"] += decode_toks
        self.stats["step_s"].append(dt)
        if prompt_toks == 0 and decode_toks > 0:
            self.stats["decode_step_s"].append(dt)
        # mixed steps: split the iteration's wall time across the phases in
        # proportion to the tokens each fed (an all-or-nothing attribution
        # inflates the minority phase's tok/s)
        total = prompt_toks + decode_toks
        if total:
            self.stats["prefill_time"] += dt * prompt_toks / total
            self.stats["decode_time"] += dt * decode_toks / total
        self.key, sub = jax.random.split(self.key)
        # logits: (B, 1, V) — the model's head already projected each row's
        # final live column only
        greedy = np.asarray(jnp.argmax(logits[:, 0], axis=-1))  # (B,)
        for b, slot in enumerate(self.slots):
            if slot.req is None or n[b] == 0:
                continue
            slot.pos += int(n[b])
            if not sampling[b]:
                continue
            if slot.req.temperature > 0:
                kb = jax.random.fold_in(sub, b)
                nxt = int(jax.random.categorical(
                    kb, logits[b, 0] / slot.req.temperature))
            else:
                nxt = int(greedy[b])
            slot.req.output.append(nxt)
            if (len(slot.req.output) >= slot.req.max_new_tokens
                    or slot.pos >= self.max_len - 1):
                slot.req.done = True
                slot.req.truncated = (
                    len(slot.req.output) < slot.req.max_new_tokens)
                finished.append(slot.req)
                slot.req = None

    def _advance_spec(self, finished: list[Request]):
        """One draft-verify round (every active slot greedy-decoding).

        Round protocol, per row at cache length P with pending token t0
        (the last sampled output, not yet fed):

          draft   k C=1 steps of the truncated model on a throwaway copy of
                  the draft cache → d_1..d_k
          verify  ONE full-model chunk over [t0, d_1..d_k] at steps=P with
                  all_logits: column i's argmax g_i is exactly what plain
                  decode would sample after committing t0..d_i
          accept  longest prefix with d_{i+1} == g_i, plus the bonus g_n —
                  n_acc+1 tokens per round, ≥1 always
          commit  roll the full cache back to the n_comm = emitted committed
                  tokens (bit-exact), then resync the authoritative draft
                  cache with one draft chunk over the same buffer at
                  n_tokens = n_comm (dead columns are exact no-ops)

        The whole round is ONE jitted dispatch (``_make_spec_round``); only
        the tiny drafted/accepted token ids come back to the host.
        """
        k = self.spec_k
        B = self.B
        steps = np.zeros((B,), np.int32)
        live = np.zeros((B,), np.int32)
        cur = np.zeros((B,), np.int32)
        budget = np.zeros((B,), np.int32)
        for b, slot in enumerate(self.slots):
            if slot.req is not None:
                steps[b] = slot.pos
                live[b] = 1
                cur[b] = slot.req.output[-1]
                # clamp the round's emission to the request budget and the
                # cache headroom (both ≥ 1 for a scheduled decode row)
                budget[b] = min(
                    slot.req.max_new_tokens - len(slot.req.output),
                    (self.max_len - 1) - slot.pos)
        t0 = time.perf_counter()
        (self.cache, self.draft_cache, draft_toks, greedy, n_acc,
         n_comm) = self._spec_round(
            self.params, self.draft_params, self.cache, self.draft_cache,
            jnp.asarray(cur), jnp.asarray(steps), jnp.asarray(live),
            jnp.asarray(budget))
        draft_toks = np.asarray(draft_toks)
        greedy = np.asarray(greedy)
        n_acc = np.asarray(n_acc)
        n_comm = np.asarray(n_comm)
        jax.block_until_ready(self.cache)
        dt = time.perf_counter() - t0
        n_live = int(live.sum())
        total_emitted = int(n_comm.sum())
        self.stats["steps"] += 1
        self.stats["decode_tokens"] += total_emitted
        self.stats["decode_time"] += dt
        self.stats["step_s"].append(dt)
        self.stats["decode_step_s"].append(dt)
        self.stats["spec_rounds"] += 1
        self.stats["spec_drafted"] += k * n_live
        self.stats["spec_accepted"] += int(np.sum(n_acc * live))
        self.stats["spec_emitted"] += total_emitted
        for b, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            # emitted tokens: the accepted draft prefix, plus the bonus
            # (verify's next-token at the first mismatch) when it fit
            emit = int(n_comm[b])
            toks = [int(draft_toks[b, j]) for j in range(min(emit, int(n_acc[b])))]
            if emit == int(n_acc[b]) + 1:
                toks.append(int(greedy[b, n_acc[b]]))
            slot.req.output.extend(toks)
            slot.pos += emit
            if (len(slot.req.output) >= slot.req.max_new_tokens
                    or slot.pos >= self.max_len - 1):
                slot.req.done = True
                slot.req.truncated = (
                    len(slot.req.output) < slot.req.max_new_tokens)
                finished.append(slot.req)
                slot.req = None
