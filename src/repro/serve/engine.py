"""Continuous-batching inference engine with chunked prefill.

A fixed pool of B slots advances through one jitted ``prefill_chunk`` per
iteration.  Each iteration the scheduler packs a *mixed* batch under a token
budget (vLLM-style chunked prefill): slots still ingesting their prompt
contribute up to ``chunk_size`` prompt tokens, slots in generation contribute
exactly one token — so a 512-token prompt costs ceil(512/C) steps instead of
512, while decodes keep flowing in the same batches.

One model call serves every row shape: ``prefill_chunk(params, cache,
tokens (B, C), steps (B,), n_tokens (B,))`` writes each slot's KV/state cache
at its own offset and masks the ragged tail columns.  The per-iteration chunk
width C is bucketed to powers of two, so the jitted step function (shared
across engines via ``step_fn`` — jit's trace cache keys it by chunk shape)
compiles O(log chunk_size) variants total.

API v2 (serve/config.py): ``Engine(model, params, EngineConfig(...))`` plus
``async generate(prompt, sampling, priority=...)`` streaming one token at a
time, ``generate_batch`` for scripts, and ``cancel(uid)``.  The legacy flat
kwargs still work through ``EngineConfig.from_legacy`` (warns once).

With ``MemoryConfig(paged=True)`` cache memory is sized in tokens, not
slots: serve/paged.py pools fixed-size pages under the sequence-axis cache
leaves, shares page-aligned prompt prefixes across requests (recurrent
families share via state snapshots), and the scheduler preempts the
lowest-priority longest-running generation when the pool runs dry —
``SchedulerConfig.policy`` picks priority-aware vs FIFO admission.

A finished slot is recycled immediately for the next queued request — no
batch drain.  Sampling: greedy or temperature.
"""

from __future__ import annotations

import asyncio
import dataclasses
import heapq
import threading
import time
import warnings
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant as qt
from repro.core import structures
from repro.parallel import NO_PARALLEL
from repro.serve import resilience as rsl
from repro.serve.config import EngineConfig, SamplingParams
from repro.serve.faults import FaultError, FaultPlan
from repro.serve.paged import PagedCache


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    priority: int = 0          # lower = more urgent (0 = interactive)
    prefix_len: int | None = None  # shared-prefix hint (tokens): recurrent
    #                            families snapshot state exactly here
    deadline_s: float | None = None  # end-to-end deadline override
    #                            (None: SchedulerConfig.deadline_s)
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False    # cache-capacity truncation ONLY (see stop_reason)
    stop_reason: str | None = None  # length | capacity | cancelled | shed |
    #                            deadline | numeric_error | error
    n_preempted: int = 0       # times this request lost its pages and re-queued
    degrade_level: int = 0     # numeric-guardrail ladder rung (resilience.py)
    degrade_path: list = dataclasses.field(default_factory=list)
    n_step_errors: int = 0     # times implicated in a step exception
    t_submit: float | None = None
    t_first: float | None = None   # first output token (TTFT = t_first-t_submit)
    t_done: float | None = None


@dataclasses.dataclass
class _Slot:
    req: Request | None = None
    pos: int = 0            # next absolute position to write
    to_feed: deque = dataclasses.field(default_factory=deque)  # prompt left
    feed: list = dataclasses.field(default_factory=list)  # full feed (prefix reg)
    reg_at: int | None = None  # page-aligned prefix-registration boundary


def _bucket(n: int) -> int:
    """Round a chunk width up to a power of two (bounds jit retraces)."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _blast_shapes(tree) -> list[tuple[int, int, int, int]]:
    """(d_out, d_in, b, r) for every BLAST linear in a params tree — reads
    the *array* shapes, so truncated draft params report their r'."""
    out = []
    if isinstance(tree, dict):
        if set(tree) - {"bias"} == {"U", "S", "V"}:
            u, v = tree["U"], tree["V"]
            # trailing 3 axes are (b, p, r) even under cycle/expert stacking
            b, p, r = (int(d) for d in u.shape[-3:])
            out.append((b * p, b * int(v.shape[-2]), b, r))
            return out
        for v in tree.values():
            out += _blast_shapes(v)
    return out


_LEGACY_WARNED = False


class Engine:
    def __init__(self, model, params, config: EngineConfig | None = None, *,
                 step_fn=None, **legacy):
        """``config``: an ``EngineConfig`` (serve/config.py) grouping the
        scheduler / memory / speculative / autotune knobs.  Passing the old
        flat kwargs (``batch_slots=…, max_len=…``) still works through
        ``EngineConfig.from_legacy`` but warns once per process.

        ``step_fn``: optionally share one ``jax.jit(model.prefill_chunk)``
        across engines — jit's trace cache keys compiled steps by chunk
        shape, so engines with the same slot count reuse each other's
        compiles (non-paged mode only; the paged step closes over the page
        geometry).

        Autotune: warm the BLAST kernel tiling cache at engine build —
        every structured linear the model dispatches is timed at this
        engine's decode width (B·1 rows) and full-chunk prefill width, and
        the winning (block_t, block_r) configs persist to
        ``AutotuneConfig.cache_path`` (JSON; see kernels/autotune.py).

        Quantize-at-load: when the model config's ``quant.weights`` knob is
        set (or a ``config.quant`` override is passed) and ``params`` are
        still float, they convert to per-block QArrays here, once.
        ``quant.cache`` must be set on the *model's* config (``init_cache``
        allocates int8 + scales from it); an override requesting cache
        quantization the model was not built with raises.

        Self-speculative decoding (``SpeculativeConfig.k > 0``): draft k
        tokens per decode round with a rank-truncated view of the SAME
        weights, verify in one all-logits ``prefill_chunk``, accept the
        exact greedy prefix — greedy outputs are token-identical to plain
        decode, rejected suffixes roll back bit-exactly."""
        if legacy:
            if config is not None:
                raise TypeError("pass either an EngineConfig or the legacy "
                                f"flat kwargs, not both: {sorted(legacy)}")
            global _LEGACY_WARNED
            if not _LEGACY_WARNED:
                _LEGACY_WARNED = True
                warnings.warn(
                    "Engine(model, params, batch_slots=…, …) is deprecated; "
                    "pass Engine(model, params, EngineConfig(...)) — see the "
                    "migration table in src/repro/serve/README.md",
                    DeprecationWarning, stacklevel=2)
            config = EngineConfig.from_legacy(**legacy)
        if config is None:
            config = EngineConfig()
        self.config = config
        sch, mem = config.scheduler, config.memory
        self.model = model
        qcfg = (config.quant if config.quant is not None
                else getattr(model.cfg, "quant", None))
        if (qcfg is not None and qcfg.cache != "none"
                and not model.cfg.cache_quant):
            # cache shapes are baked into the model at construction
            raise ValueError(
                "quant.cache is a model-construction knob: build the model "
                "with ArchConfig.quant (init_cache allocates int8 + scales "
                "from it); the Engine quant= override only covers weights")
        if (qcfg is not None and qcfg.weight_bits is not None
                and not qt.tree_is_quantized(params)):
            params = jax.jit(
                lambda p: model.quantize_params(p, qcfg))(params)
        if qcfg is not None and getattr(qcfg, "activations", "none") != "none":
            # trace-time toggle: every step function jitted from here on
            # contracts int8 activation codes (W8A8/W4A8 kernels)
            structures.set_activations(qcfg.activations)
        self.params = params
        self.B = sch.slots
        self.max_len = mem.max_len
        self.chunk = max(1, int(sch.chunk_size))
        self.token_budget = (self.B * self.chunk if sch.token_budget is None
                             else max(1, int(sch.token_budget)))
        self.policy = sch.policy
        if self.policy not in ("priority", "fifo"):
            raise ValueError(f"unknown scheduler policy {sch.policy!r}")

        # -- cache storage: slot-static tree, or the paged pool -------------
        self._pc: PagedCache | None = None
        if mem.paged:
            n_pp = mem.max_len // mem.page_size
            pages = (self.B * n_pp + 1 if mem.pages is None
                     else int(mem.pages))
            snap = (max(4, pages // 4) if mem.snap_slots is None
                    else int(mem.snap_slots))
            self._pc = PagedCache(model, self.B, mem.max_len, mem.page_size,
                                  pages, snap, mem.prefix_sharing)
            self._paged_step = self._pc.make_step()
            self.cache = None
        else:
            self.cache = model.init_cache(self.B, mem.max_len)
            self._template = self.cache  # pristine zero cache (reset source)
        # per-leaf batch-axis position (stacked layer caches carry a leading
        # "layers" axis, so batch is NOT uniformly axis 0)
        axes = model.cache_axes()
        is_axes = lambda x: x is None or (isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
        self._batch_axis = jax.tree.map(
            lambda ax: ax.index("batch"), axes, is_leaf=is_axes)
        self.slots = [_Slot() for _ in range(self.B)]
        self._rr = 0  # round-robin start for budget allocation
        self.queue: list = []   # heap of (prio_key, seq, Request)
        self._seq = 0
        self.key = jax.random.PRNGKey(config.seed)
        self._step = step_fn if step_fn is not None else jax.jit(
            model.prefill_chunk)
        self.finished: list[Request] = []   # everything ever completed
        self.stats = {"steps": 0, "prefill_tokens": 0, "decode_tokens": 0,
                      "prefill_time": 0.0, "decode_time": 0.0,
                      # per-step wall times: all steps + pure-decode steps
                      # (benchmarks reduce these to latency percentiles)
                      "step_s": [], "decode_step_s": [],
                      # speculative rounds: drafted/accepted counts per round
                      "spec_rounds": 0, "spec_drafted": 0, "spec_accepted": 0,
                      "spec_emitted": 0,
                      # multi-tenant serving signals
                      "preemptions": 0, "prefix_hit_tokens": 0,
                      "prompt_tokens_submitted": 0, "queue_depth": [],
                      # resilience counters (serve/resilience.py)
                      "numeric_trips": 0, "degrade_spec_off": 0,
                      "degrade_act_float": 0, "numeric_error_failures": 0,
                      "step_errors": 0, "requeues": 0, "shed": 0,
                      "deadline_expired": 0}
        # async streaming state
        self._lock = threading.Lock()
        self._streams: dict[int, tuple[Request, asyncio.Queue]] = {}
        self._driver: asyncio.Task | None = None
        self._auto_uid = 1 << 40

        # -- resilience: guardrails, fault plan, watchdog, fault isolation --
        res = config.resilience
        self.resilience = res
        self.health = rsl.Health()
        self._deadline_s = sch.deadline_s
        self._deadlines_armed = sch.deadline_s is not None
        self._guard = (rsl.Guardrail(res.logit_absmax) if res.guardrails
                       else None)
        self.fault_plan: FaultPlan | None = (
            FaultPlan.from_spec(res.fault_spec) if res.fault_spec else None)
        self._iter = 0                    # step attempts (incl. failed ones)
        self._step_inflight_since: float | None = None   # watchdog stamp
        self._last_stepped: set[int] = set()
        self._probe: list[list[int]] = []   # fault-bisect uid groups
        self._cleared: set[int] = set()     # uids proven innocent this hunt
        self._serve_float = False    # alternation toggle: rung-2 isolation
        self._step_float = None      # lazy jit twins traced with act="none"
        self._paged_step_float = None
        self._watchdog = (rsl.Watchdog(self, res.watchdog_deadline_s)
                          if res.watchdog_deadline_s else None)

        self.spec_k = max(0, int(config.speculative.k))
        self.draft_rank_frac = float(config.speculative.draft_rank_frac)
        if self.spec_k:
            needed = ("draft_plan", "truncate_params", "rollback_cache")
            if not all(hasattr(model, a) for a in needed):
                raise ValueError(
                    "speculative decoding needs a model with "
                    f"{needed} (repro.models.transformer.LM)")
            self.draft_plan = model.draft_plan(self.params,
                                               self.draft_rank_frac)
            plan = self.draft_plan
            self.draft_params = jax.jit(
                lambda p: model.truncate_params(p, plan))(self.params)
            if config.prestack and hasattr(model, "prestack_params"):
                self.draft_params = jax.jit(model.prestack_params)(
                    self.draft_params)
            # the draft cache stays slot-static even in paged mode: it is
            # rewound/resynced every round, so it never holds a prefix worth
            # sharing, and k+1-token rounds keep its working set tiny
            self.draft_cache = model.init_cache(self.B, self.max_len)
            self._draft_template = self.draft_cache
            self._spec_round = jax.jit(self._make_spec_round())
            if self._pc is not None:
                self._paged_spec = self._pc.make_spec_step(
                    self._make_spec_round())
        if config.prestack and hasattr(model, "prestack_params"):
            self.params = jax.jit(model.prestack_params)(self.params)

        # -- mesh parallelism: same engine code from 1 to N devices ---------
        self.parallel = getattr(model, "parallel", NO_PARALLEL)
        if config.mesh is not None:
            from repro.launch.mesh import parse_mesh
            dp, tp = parse_mesh(config.mesh)
            if ((dp, tp) != (1, 1)
                    and (not self.parallel.active
                         or self.parallel.dp_size != dp
                         or self.parallel.tp_size != tp)):
                raise ValueError(
                    f"EngineConfig.mesh={config.mesh!r} wants a {dp}x{tp} "
                    "mesh but the model was not built on one — construct it "
                    "with build_model(cfg, make_parallel(make_serving_mesh("
                    f"{dp}, {tp}), serve=True)) so params, activations and "
                    "collectives agree")
        self.sharding_report: dict | None = None
        if self.parallel.active:
            self._shard_state()
        if config.autotune.enabled:
            self._warm_autotune(qcfg, config.autotune.cache_path)

    def _shard_state(self) -> None:
        """Lay params and caches out on the model's mesh.

        Runs AFTER quantize/truncate/prestack, so the specs from
        launch/sharding.py land on the final pytrees: QArray ``{q, scale}``
        leaves get congruent specs (scales follow their codes' row/block
        axis) and prestacked GroupBundles shard their trailing rank/output
        axes per the bundle plan.  ``serve=True`` parallel means params are
        TP-sharded and data-replicated; slot caches shard batch over "data";
        the paged pool replicates pages (globally indexed) but TP-shards
        heads/state dims.  Also flips the trace-time TP-mesh toggle so
        Pallas grouped applies compiled from here on run one launch per
        bundle per shard, and records the replicated-leaf report the
        benchmarks surface."""
        from repro.launch import sharding as shd
        par = self.parallel
        axes = self.model.axes()
        self.params = jax.device_put(
            self.params, shd.tree_shardings(self.params, axes, par))
        caxes = self.model.cache_axes()
        if self._pc is not None:
            self._pc.shard(par)
        else:
            csh = shd.tree_shardings(self.cache, caxes, par)
            self.cache = jax.device_put(self.cache, csh)
            self._template = self.cache
        if self.spec_k:
            self.draft_params = jax.device_put(
                self.draft_params,
                shd.tree_shardings(self.draft_params, axes, par))
            self.draft_cache = jax.device_put(
                self.draft_cache,
                shd.tree_shardings(self.draft_cache, caxes, par))
            self._draft_template = self.draft_cache
        if par.tp_size > 1 and par.model_axis is not None:
            structures.set_tp_mesh(par.mesh, par.model_axis)
        self.sharding_report = shd.replication_report(self.params, axes, par)

    def _make_spec_round(self):
        """Build the fused draft-verify round: ONE jitted dispatch per round.

        Drafting k tokens with host-side control costs k device syncs plus
        k+3 dispatches — more wall time than the k+1 plain steps it
        replaces.  Fusing the draft scan, the all-logits verify, the greedy
        accept, the cache rollback and the draft-cache resync into a single
        jitted function leaves one dispatch and one host transfer (the
        drafted/accepted token ids) per round.
        """
        model, k = self.model, self.spec_k
        Cv = _bucket(k + 1)
        absmax = (self.resilience.logit_absmax if self.resilience.guardrails
                  else None)

        def spec_round(p, dp, cache, dcache, cur, steps, live, budget):
            B = cur.shape[0]
            # -- draft: k single-token steps on a throwaway dcache copy
            def body(carry, i):
                c, tok = carry
                lg, c = model.prefill_chunk(dp, c, tok[:, None], steps + i,
                                            live)
                nxt = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)
                return (c, nxt), nxt
            _, seq = jax.lax.scan(body, (dcache, cur),
                                  jnp.arange(k, dtype=jnp.int32))
            draft_toks = seq.T                                     # (B, k)
            # -- verify: one full-model all-logits chunk over [t0, d_1..d_k]
            pad = jnp.zeros((B, Cv - k - 1), jnp.int32)
            vt = jnp.concatenate([cur[:, None], draft_toks, pad], axis=1)
            lg, new_cache = model.prefill_chunk(
                p, cache, vt, steps, live * (k + 1),
                all_logits=True, collect_states=True)
            greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)     # (B, Cv)
            # -- accept: longest greedy-matching draft prefix (+ bonus)
            match = draft_toks == greedy[:, :k]
            n_acc = jnp.where(match.all(axis=1), k,
                              jnp.argmax(~match, axis=1)).astype(jnp.int32)
            n_comm = jnp.minimum(n_acc + 1, budget) * live
            # -- guardrail: per-row health of the verify logits (the k+1
            # real columns only — bucket padding never gates a row)
            ok = structures.row_health(lg[:, :k + 1], absmax=absmax)
            # -- commit: bit-exact rewind + one ragged draft resync chunk
            cache = model.rollback_cache(cache, new_cache, steps, n_comm)
            _, dcache = model.prefill_chunk(dp, dcache, vt, steps, n_comm)
            return cache, dcache, draft_toks, greedy, n_acc, n_comm, ok

        return spec_round

    def _warm_autotune(self, qcfg, cache_path: str | None):
        """Tune the fused-kernel tiling for every unique BLAST shape this
        model dispatches, at the decode (B rows) and full-prefill-chunk
        widths this engine will actually run, then persist the cache."""
        from repro.kernels import autotune as at

        at.enable(cache_path)
        kind = {None: "float", 8: "int8", 4: "int4"}[
            qcfg.weight_bits if qcfg is not None else None]
        act = (getattr(qcfg, "activations", "none")
               if qcfg is not None else "none")
        dtype = jnp.dtype(self.model.cfg.compute_dtype)
        widths = sorted({self.B, self.B * _bucket(self.chunk)})
        shapes = []
        for spec in getattr(self.model, "linear_specs", list)():
            if spec.kind == "blast":
                shapes.append((spec.d_out, spec.d_in, spec.meta["b"],
                               spec.meta["r"]))
        if self.spec_k:
            # the draft model dispatches the same blocked shapes at the
            # truncated ranks — warm those too (draft steps run at decode
            # width and at the verify chunk width)
            shapes += _blast_shapes(self.draft_params)
        tp = self.parallel.tp_size
        if tp > 1:
            # under shard_map each device contracts its rank shard, so the
            # kernels launch at the LOCAL rank — warm those keys too
            shapes += [(m, n, b, r // tp)
                       for (m, n, b, r) in shapes if r % tp == 0]
        seen = set()
        for d_out, d_in, b, r in shapes:
            for T in widths:
                key = (T, d_out, d_in, b, r)
                if key in seen:
                    continue
                seen.add(key)
                at.tune_blast(T, d_out, d_in, b, r, dtype=dtype,
                              kind=kind, act=act, reps=1)
        at.save()

    # -- public ---------------------------------------------------------------

    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(f"request {req.uid}: empty prompt (generation "
                             "needs at least one conditioning token)")
        with self._lock:
            self._submit_locked(req)

    def _submit_locked(self, req: Request):
        req.t_submit = time.perf_counter()
        if req.deadline_s is not None:
            self._deadlines_armed = True
        self.stats["prompt_tokens_submitted"] += len(req.prompt)
        self._enqueue(req)

    def _prio(self, req: Request) -> int:
        """Effective scheduling priority: FIFO mode ignores priority
        classes entirely (arrival order, no priority preemption) — it is
        the baseline the serving benchmark contrasts against."""
        return req.priority if self.policy == "priority" else 0

    def _enqueue(self, req: Request):
        self._seq += 1
        heapq.heappush(self.queue, (self._prio(req), self._seq, req))

    def run(self, max_iters: int = 10_000) -> list[Request]:
        """Drive until queue + slots drain.  Returns completed requests."""
        n0 = len(self.finished)
        for _ in range(max_iters):
            with self._lock:
                if not self._tick_locked():
                    break
        return self.finished[n0:]

    def tick(self) -> bool:
        """One scheduler iteration (public: trace-driven benchmarks submit
        between ticks).  Returns False once queue + slots are drained."""
        with self._lock:
            return self._tick_locked()

    def _tick_locked(self) -> bool:
        """One scheduler iteration.  Returns False when fully drained.

        The jitted-step block runs under fault isolation: an exception
        never escapes the tick — the implicated request is failed (or the
        batch bisected until it is found) and every other active request is
        re-queued through deterministic recompute-on-resume.  The engine
        itself cannot be crashed by a poisoned step."""
        self._expire_deadlines()
        self._shed_overflow()
        self._admit()
        self.stats["queue_depth"].append(len(self.queue))
        if not any(s.req for s in self.slots):
            return bool(self.queue)
        try:
            if self.spec_k and self._spec_eligible():
                self._advance_spec(self.finished)
            else:
                self._advance(self.finished)
        except Exception as exc:   # driver fault isolation — never the batch
            self._step_inflight_since = None
            self._handle_step_error(exc)
            return True
        if self._probe and self._last_stepped:
            # a clean step clears its participants: the culprit cannot have
            # been among them, so the bisect narrows
            self._cleared |= self._last_stepped
            self._probe[0] = [u for u in self._probe[0]
                              if u not in self._last_stepped]
            self._prune_probe()
        return True

    def generate_batch(self, prompts, sampling: SamplingParams | None = None,
                       priority: int = 0) -> list[Request]:
        """Sync convenience wrapper: submit every prompt, drive to drain,
        return the requests in input order."""
        sampling = sampling or SamplingParams()
        reqs = []
        for prompt in prompts:
            with self._lock:
                uid = self._auto_uid
                self._auto_uid += 1
            req = Request(uid=uid, prompt=list(prompt),
                          max_new_tokens=sampling.max_new_tokens,
                          temperature=sampling.temperature, priority=priority)
            reqs.append(req)
            self.submit(req)
        self.run()
        return reqs

    async def generate(self, prompt, sampling: SamplingParams | None = None,
                       *, priority: int = 0, prefix_len: int | None = None,
                       uid: int | None = None,
                       deadline_s: float | None = None):
        """Async token stream for one request.  Closing the iterator early
        (client disconnect) cancels the request and releases its pages
        immediately.  All concurrent ``generate`` calls batch through one
        shared driver task, so streams interleave at engine-iteration
        granularity."""
        sampling = sampling or SamplingParams()
        with self._lock:
            if uid is None:
                uid = self._auto_uid
                self._auto_uid += 1
        req = Request(uid=uid, prompt=list(prompt),
                      max_new_tokens=sampling.max_new_tokens,
                      temperature=sampling.temperature, priority=priority,
                      prefix_len=prefix_len, deadline_s=deadline_s)
        q: asyncio.Queue = asyncio.Queue()
        with self._lock:
            self._streams[uid] = (req, q)
            self._submit_locked(req)
        self._ensure_driver()
        try:
            while True:
                tok = await q.get()
                if tok is None:
                    break
                yield tok
        finally:
            if not req.done:
                self.cancel(uid)
            with self._lock:
                self._streams.pop(uid, None)

    def cancel(self, uid: int):
        """Abort a queued or running request: its slot (pages, state rows,
        speculative draft-cache row) is released immediately, not at the
        next natural recycle."""
        with self._lock:
            for i, (_, _, req) in enumerate(self.queue):
                if req.uid == uid:
                    self.queue.pop(i)
                    heapq.heapify(self.queue)
                    self._finish(req, "cancelled")
                    return
            for b, slot in enumerate(self.slots):
                if slot.req is not None and slot.req.uid == uid:
                    req = slot.req
                    self._release_slot(b)
                    self._finish(req, "cancelled")
                    return

    def _ensure_driver(self):
        if self._driver is None or self._driver.done():
            self._driver = asyncio.get_running_loop().create_task(
                self._drive())

    async def _drive(self):
        """Single background task batching all async ``generate`` streams:
        run one engine iteration in a worker thread, flush freshly emitted
        tokens to each stream's queue, repeat until drained.  State is
        guarded by ``self._lock`` (``cancel``/``submit`` may run on the
        loop thread while an iteration runs in the worker)."""
        emitted: dict[int, int] = {}
        while True:
            with self._lock:
                work = bool(self.queue) or any(s.req for s in self.slots)
            if not work:
                break
            try:
                await asyncio.to_thread(self._tick_threadsafe)
            except Exception as exc:
                # _tick_locked already contains step faults; anything that
                # still escapes is a driver bug — fail every in-flight
                # request (streams see their terminator) instead of wedging
                self.health.record_error(exc)
                self.health.degrade(f"driver: {type(exc).__name__}")
                with self._lock:
                    for b, s in enumerate(self.slots):
                        if s.req is not None:
                            req = s.req
                            self._release_slot(b)
                            self._finish(req, "error")
                    while self.queue:
                        _, _, req = heapq.heappop(self.queue)
                        self._finish(req, "error")
                self._flush_streams(emitted)
                break
            self._flush_streams(emitted)
        self._flush_streams(emitted)

    def _tick_threadsafe(self):
        with self._lock:
            self._tick_locked()

    def _flush_streams(self, emitted: dict[int, int]):
        with self._lock:
            streams = list(self._streams.values())
        for req, q in streams:
            sent = emitted.get(req.uid, 0)
            for tok in req.output[sent:]:
                q.put_nowait(tok)
            emitted[req.uid] = len(req.output)
            if req.done:
                q.put_nowait(None)
                emitted.pop(req.uid, None)
                with self._lock:
                    self._streams.pop(req.uid, None)

    def _spec_eligible(self) -> bool:
        """Speculative rounds run only when every active slot is in greedy
        decode (prompt fully ingested, ≥1 sampled token) at degradation
        rung 0.  Prefill chunks and temperature sampling use the plain path
        — exactness of the accept rule needs argmax on both sides — and a
        guardrail-tripped request has already traded its draft away
        (ladder rung 1: ``spec_off``)."""
        active = [s for s in self.slots if s.req is not None]
        return bool(active) and all(
            not s.to_feed and s.req.output and s.req.temperature == 0
            and s.req.degrade_level == 0
            for s in active)

    def throughput(self) -> dict:
        """Prefill / decode tokens-per-second split from engine stats."""
        s = self.stats
        out = {
            "steps": s["steps"],
            "prefill_tok_s": (s["prefill_tokens"] / s["prefill_time"]
                              if s["prefill_time"] else 0.0),
            "decode_tok_s": (s["decode_tokens"] / s["decode_time"]
                             if s["decode_time"] else 0.0),
        }
        if self.spec_k:
            out["spec_rounds"] = s["spec_rounds"]
            out["acceptance_rate"] = (s["spec_accepted"] / s["spec_drafted"]
                                      if s["spec_drafted"] else 0.0)
            out["tokens_per_round"] = (s["spec_emitted"] / s["spec_rounds"]
                                       if s["spec_rounds"] else 0.0)
        return out

    def overloaded(self) -> bool:
        """Admission-control signal the HTTP frontend turns into 429 +
        Retry-After.  Lock-free on purpose: a hung step holds the engine
        lock, and shedding decisions must keep answering while it does."""
        hw = self.resilience.queue_high_water
        if hw is None:
            return False
        n_active = sum(1 for s in self.slots if s.req is not None)
        return len(self.queue) + n_active >= hw

    def healthz(self) -> dict:
        """Live condition snapshot for ``GET /healthz``.  Reads only the
        health lock (never the engine lock): this must answer while a step
        is wedged — detecting exactly that is the watchdog's job."""
        snap = self.health.snapshot()
        snap["queue_depth"] = len(self.queue)
        snap["active"] = sum(1 for s in self.slots if s.req is not None)
        snap["slots"] = self.B
        snap["overloaded"] = self.overloaded()
        if self._pc is not None:
            snap.update(self._pc.occupancy())
        return snap

    def resilience_report(self) -> dict:
        """Resilience counters + fault-plan fire log (chaos benchmark)."""
        s = self.stats
        out = {"health": self.health.snapshot(),
               "numeric_trips": s["numeric_trips"],
               "degrade_spec_off": s["degrade_spec_off"],
               "degrade_act_float": s["degrade_act_float"],
               "numeric_error_failures": s["numeric_error_failures"],
               "step_errors": s["step_errors"],
               "requeues": s["requeues"],
               "shed": s["shed"],
               "deadline_expired": s["deadline_expired"]}
        if self.fault_plan is not None:
            out["faults"] = self.fault_plan.report()
        return out

    def close(self):
        """Stop the watchdog thread (idempotent).  The engine itself holds
        no other background resources."""
        if self._watchdog is not None:
            self._watchdog.stop()
            self._watchdog = None

    def sla_report(self) -> dict:
        """TTFT / TPOT percentiles per priority class, plus the multi-tenant
        counters (preemption + prefix-hit rates, queue depth).

        Every finished request contributes to its class's ``requests`` and
        ``stop_reasons`` counts, but only requests that actually produced a
        first token contribute latency samples — a class whose requests were
        all shed/cancelled/expired reports explicit ``None`` percentiles
        rather than a fabricated 0.0 (or a ZeroDivisionError)."""
        def pct(xs, q):
            return float(np.percentile(np.asarray(xs), q)) if xs else None

        classes: dict[int, dict] = {}
        for r in self.finished:
            c = classes.setdefault(r.priority, {"ttft": [], "tpot": [],
                                                "requests": 0,
                                                "stop_reasons": {}})
            c["requests"] += 1
            reason = r.stop_reason or "unknown"
            c["stop_reasons"][reason] = c["stop_reasons"].get(reason, 0) + 1
            if r.t_submit is None or r.t_first is None:
                continue
            c["ttft"].append(r.t_first - r.t_submit)
            if r.t_done is not None and len(r.output) > 1:
                c["tpot"].append((r.t_done - r.t_first)
                                 / (len(r.output) - 1))
        per_class = {
            str(p): {"requests": c["requests"],
                     "completed": len(c["ttft"]),
                     "stop_reasons": c["stop_reasons"],
                     "ttft_p50_s": pct(c["ttft"], 50),
                     "ttft_p99_s": pct(c["ttft"], 99),
                     "tpot_p50_s": pct(c["tpot"], 50),
                     "tpot_p99_s": pct(c["tpot"], 99)}
            for p, c in sorted(classes.items())}
        s = self.stats
        out = {
            "classes": per_class,
            "preemptions": s["preemptions"],
            "preemption_rate": (s["preemptions"] / len(self.finished)
                                if self.finished else 0.0),
            "prefix_hit_tokens": s["prefix_hit_tokens"],
            "prefix_hit_rate": (s["prefix_hit_tokens"]
                                / s["prompt_tokens_submitted"]
                                if s["prompt_tokens_submitted"] else 0.0),
            "queue_depth_p50": pct(s["queue_depth"], 50) or 0.0,
            "queue_depth_max": (max(s["queue_depth"])
                                if s["queue_depth"] else 0),
            "resilience": self.resilience_report(),
        }
        if self._pc is not None:
            out["pool_tokens"] = self._pc.pool_tokens()
            out["pool_pages_free"] = self._pc.pages.n_free
            out["cache_bytes"] = self._pc.nbytes()
        return out

    # -- internals --------------------------------------------------------------

    def _reset_slot(self, b: int):
        def reset(bax, c, t):
            idx = (slice(None),) * bax + (b,)
            return c.at[idx].set(t[idx])
        if self._pc is not None:
            self._pc.reset_slot(b)
        else:
            self.cache = jax.tree.map(reset, self._batch_axis, self.cache,
                                      self._template)
        if self.spec_k:
            self.draft_cache = jax.tree.map(
                reset, self._batch_axis, self.draft_cache,
                self._draft_template)

    def _release_slot(self, b: int):
        """Free everything a departing request holds: its pages, its
        state-leaf rows, and its speculative draft-cache row."""
        slot = self.slots[b]
        if self._pc is not None:
            self._pc.free_slot(b)
        self._reset_slot(b)
        slot.req = None
        slot.to_feed = deque()
        slot.feed = []
        slot.reg_at = None
        slot.pos = 0

    def _finish(self, req: Request, reason: str):
        req.done = True
        req.stop_reason = reason
        req.truncated = reason == "capacity"
        req.t_done = time.perf_counter()
        self.finished.append(req)

    def _finish_slot(self, b: int, reason: str):
        req = self.slots[b].req
        self._release_slot(b)
        self._finish(req, reason)

    def _preempt(self, b: int):
        """Evict slot b's request: free its pages and state, re-queue it for
        recompute-on-resume (its sampled output is kept; the resumed request
        re-feeds prompt + output, and may hit its own registered prefix)."""
        req = self.slots[b].req
        self._release_slot(b)
        req.n_preempted += 1
        self.stats["preemptions"] += 1
        # the resume re-feeds prompt + output; count it into the prefix-hit
        # denominator so re-admission hits keep the rate a true fraction
        self.stats["prompt_tokens_submitted"] += (len(req.prompt)
                                                  + len(req.output))
        self._enqueue(req)

    # -- resilience internals (serve/resilience.py, serve/faults.py) -----------

    def _requeue_slot(self, b: int):
        """Release slot b and re-queue its request through the deterministic
        recompute-on-resume path (same mechanics as preemption: the sampled
        output is kept, the resume re-feeds prompt + output, and the cache
        row is rebuilt from tokens — a poisoned row is never patched)."""
        req = self.slots[b].req
        self._release_slot(b)
        self.stats["requeues"] += 1
        self.stats["prompt_tokens_submitted"] += (len(req.prompt)
                                                  + len(req.output))
        self._enqueue(req)

    def _numeric_trip(self, b: int):
        """Walk slot b's request one rung down the degradation ladder
        (resilience.DEGRADE_LADDER): spec off → float activations → fail
        with ``numeric_error``.  Only this request is touched."""
        req = self.slots[b].req
        self.stats["numeric_trips"] += 1
        with self.health._lock:
            self.health.numeric_trips += 1
        req.degrade_level += 1
        if req.degrade_level > len(rsl.DEGRADE_LADDER):
            self._release_slot(b)
            self.stats["numeric_error_failures"] += 1
            self._finish(req, "numeric_error")
            return
        rung = rsl.DEGRADE_LADDER[req.degrade_level - 1]
        req.degrade_path.append(rung)
        self.stats["degrade_" + rung] += 1
        self._requeue_slot(b)

    def _poll_faults_pre(self, sched_uids):
        """Arm the pre-dispatch fault kinds: an injected stall runs inside
        the already-open watchdog window; an injected driver error raises
        out of the step exactly like an opaque XLA failure would."""
        plan = self.fault_plan
        if plan is None:
            return
        for f in plan.poll("slow_step", self._iter, sched_uids):
            time.sleep(f.delay_s)
        for f in plan.poll("driver_error", self._iter, sched_uids):
            raise FaultError(
                f"injected driver fault at iteration {self._iter} "
                f"({f.describe()})", uid=f.uid if f.known else None)

    def _inject_nan(self, ok: np.ndarray, sched_uids) -> np.ndarray:
        """Merge due nan_logits faults into a step's ok mask (injection is
        a detector-level poison: the row is treated exactly as if the
        guardrail had caught real NaNs, without writing NaNs into the
        cache that deterministic recovery then depends on)."""
        plan = self.fault_plan
        if plan is not None:
            for f in plan.poll("nan_logits", self._iter, sched_uids):
                for b, slot in enumerate(self.slots):
                    if slot.req is not None and slot.req.uid == f.uid:
                        ok[b] = False
        return ok

    def _row_health(self, logits, sched_uids) -> np.ndarray | None:
        """(B,) ok mask for this step's logits, or None when the guardrail
        is off (no detector → injected nan faults stay dormant too)."""
        if self._guard is None:
            return None
        ok = np.asarray(self._guard.ok_rows(logits)).astype(bool)
        return self._inject_nan(ok, sched_uids)

    def _note_step_done(self, dt: float):
        """A step finished cleanly: once no culprit hunt is in flight and
        the step came in under the watchdog deadline, the engine is
        healthy again."""
        h = self.health
        if h.state != "degraded" or self._probe:
            return
        if self._watchdog is not None and dt > self._watchdog.deadline_s:
            return
        h.recover()

    def _requeue_error(self, b: int):
        """Requeue slot b after a step exception, failing the request
        outright once it has been implicated more than
        ``ResilienceConfig.step_error_limit`` times (bounds livelock under
        a persistent whole-batch fault)."""
        req = self.slots[b].req
        req.n_step_errors += 1
        if req.n_step_errors > self.resilience.step_error_limit:
            self._release_slot(b)
            self._finish(req, "error")
            return
        self._requeue_slot(b)

    def _handle_step_error(self, exc: Exception):
        """Contain a step exception: fail only the implicated request,
        requeue everything else through recompute-on-resume.  When the
        exception does not name a culprit (``exc.uid``), bisect across
        subsequent ticks — admission is restricted to one probe group at a
        time until a failing step leaves a singleton suspect."""
        self.stats["step_errors"] += 1
        self.health.record_error(exc)
        self.health.degrade(f"step error: {type(exc).__name__}: {exc}")
        self._last_stepped = set()
        active = {s.req.uid: b for b, s in enumerate(self.slots)
                  if s.req is not None}
        culprit = None
        uid = getattr(exc, "uid", None)
        if uid is not None and uid in active:
            culprit = uid
        else:
            suspects = sorted(u for u in active if u not in self._cleared)
            if not suspects:
                # the innocence evidence was wrong (e.g. a fault arming
                # later than the hunt began): restart over everything active
                self._cleared = set()
                suspects = sorted(active)
            if len(suspects) == 1:
                culprit = suspects[0]
            else:
                self._probe = rsl.bisect_groups(suspects)
        if culprit is not None:
            b = active.pop(culprit)
            req = self.slots[b].req
            self._release_slot(b)
            self._finish(req, "error")
            self._probe, self._cleared = [], set()
        # every other active request re-queues: the paged tables were
        # already mutated for this iteration's allocation, so nothing may
        # keep running on it
        for b, s in enumerate(self.slots):
            if s.req is not None:
                self._requeue_error(b)

    def _prune_probe(self):
        """Drop probe uids that are gone (finished) or proven innocent;
        advance to the next group when the head empties; end the hunt when
        no groups remain."""
        if not self._probe:
            return
        present = {r.uid for _, _, r in self.queue}
        present |= {s.req.uid for s in self.slots if s.req is not None}
        while self._probe:
            self._probe[0] = [u for u in self._probe[0]
                              if u in present and u not in self._cleared]
            if self._probe[0]:
                return
            self._probe.pop(0)
        self._cleared = set()

    def _expire_deadlines(self):
        """Fail queued and running requests past their end-to-end deadline
        (``Request.deadline_s`` overriding ``SchedulerConfig.deadline_s``),
        measured from submit — a deadline survives preemption and requeues."""
        if not self._deadlines_armed:
            return
        now = time.perf_counter()

        def expired(req: Request) -> bool:
            dl = (req.deadline_s if req.deadline_s is not None
                  else self._deadline_s)
            return (dl is not None and req.t_submit is not None
                    and now - req.t_submit > dl)

        keep = [item for item in self.queue if not expired(item[2])]
        if len(keep) != len(self.queue):
            for item in self.queue:
                if expired(item[2]):
                    self.stats["deadline_expired"] += 1
                    self._finish(item[2], "deadline")
            self.queue = keep
            heapq.heapify(self.queue)
        for b, slot in enumerate(self.slots):
            if slot.req is not None and expired(slot.req):
                self.stats["deadline_expired"] += 1
                self._finish_slot(b, "deadline")

    def _shed_overflow(self):
        """Admission control: above ``ResilienceConfig.queue_high_water``
        total requests in flight, shed the lowest-priority newest queued
        work (``stop_reason="shed"``) — the HTTP frontend surfaces the same
        signal as 429 + Retry-After before requests ever reach the queue."""
        hw = self.resilience.queue_high_water
        if hw is None:
            return
        n_active = sum(1 for s in self.slots if s.req is not None)
        while self.queue and len(self.queue) + n_active > hw:
            i = max(range(len(self.queue)),
                    key=lambda j: (self.queue[j][0], self.queue[j][1]))
            _, _, req = self.queue.pop(i)
            heapq.heapify(self.queue)
            self.stats["shed"] += 1
            self._finish(req, "shed")

    def _queue_head_idx(self) -> int | None:
        """Index of the next admissible queued request: the heap head
        normally; during a culprit hunt, the best-keyed request from the
        current probe group (or already proven innocent)."""
        if not self.queue:
            return None
        if not self._probe:
            return 0
        allowed = set(self._probe[0]) | self._cleared
        best = None
        for i, (p, s, req) in enumerate(self.queue):
            if req.uid in allowed and (best is None or (p, s) < best[0]):
                best = ((p, s), i)
        return None if best is None else best[1]

    def _float_plain_step(self):
        """Lazy jit twin of the step function traced with float activations
        (ladder rung 2).  A distinct jit object traces separately, so the
        int8-activation fast path keeps its own compiled programs; weights
        stay quantized — only the per-token activation rounding is gone."""
        if self._step_float is None:
            jfn = jax.jit(self.model.prefill_chunk)

            def call(*a):
                with structures.activations("none"):
                    return jfn(*a)
            self._step_float = call
        return self._step_float

    def _float_paged_step(self):
        if self._paged_step_float is None:
            jfn = self._pc.make_step()

            def call(*a):
                with structures.activations("none"):
                    return jfn(*a)
            self._paged_step_float = call
        return self._paged_step_float

    def _victim(self, below: int, exclude: set[int]) -> int | None:
        """Deterministic preemption victim: among active slots with strictly
        lower priority than ``below`` (higher number), the longest-running
        (most output tokens), ties to the highest slot index."""
        best = None
        for b, slot in enumerate(self.slots):
            if b in exclude or slot.req is None:
                continue
            if self._prio(slot.req) <= below:
                continue
            key = (self._prio(slot.req), len(slot.req.output), b)
            if best is None or key > best[0]:
                best = (key, b)
        return None if best is None else best[1]

    # -- admission -------------------------------------------------------------

    def _pages_needed(self, feed_len: int, hit: int) -> int:
        """Pages a request still needs to ingest its feed and sample once
        (admission gate; decode growth beyond that is handled by the
        in-step escalation)."""
        pc = self._pc
        if pc is None or not pc.has_paged:
            return 0
        return (feed_len + 1 + pc.ps - 1) // pc.ps - hit // pc.ps

    def _admit(self):
        self._prune_probe()
        for b, slot in enumerate(self.slots):
            if slot.req is not None or not self.queue:
                continue
            qi = self._queue_head_idx()
            if qi is None:
                return   # culprit hunt: nothing admissible this tick
            item = self.queue.pop(qi)
            heapq.heapify(self.queue)
            prio, _, req = item
            feed = req.prompt + req.output   # resume recomputes its output
            if self._pc is not None:
                hit = self._pc.prefix_lookup(feed)
                hit_len = hit.length if hit else 0
                need = self._pages_needed(len(feed), hit_len)
                # admission never preempts equal-or-higher priority work and
                # never waits on it either: evict cold prefix entries, then
                # strictly-lower-priority victims, else leave it queued
                while need > self._pc.pages.n_free:
                    if self._pc.evict_one(require_free=True):
                        # eviction can invalidate the hit entry — re-resolve
                        hit = self._pc.prefix_lookup(feed)
                        hit_len = hit.length if hit else 0
                        need = self._pages_needed(len(feed), hit_len)
                        continue
                    v = self._victim(self._prio(req), exclude=set())
                    if v is not None:
                        self._preempt(v)
                        continue
                    break
                if need > self._pc.pages.n_free:
                    if any(s.req for s in self.slots):
                        # wait for running work to free pages — the original
                        # heap key goes back, so arrival order is preserved
                        heapq.heappush(self.queue, item)
                        return
                    # sole candidate and the whole pool is still too small:
                    # this request can never fit
                    self._finish(req, "capacity")
                    continue
            self._reset_slot(b)
            slot.req = req
            slot.pos = 0
            slot.feed = feed
            slot.to_feed = deque(feed)
            slot.reg_at = None
            if self._pc is not None:
                if hit is not None:
                    self._pc.prefix_admit(b, hit)
                    slot.pos = hit.length
                    slot.to_feed = deque(feed[hit.length:])
                    self.stats["prefix_hit_tokens"] += hit.length
                self._plan_registration(b, slot, hit)

    def _plan_registration(self, b: int, slot: _Slot, hit):
        """Decide where this request registers its prompt prefix.

        Pure-KV families register every page-aligned level once the prompt
        is ingested (page refs are free).  Families with recurrent/ring
        state pay one snapshot slot per entry, so they register a single
        boundary — the request's ``prefix_len`` hint, else the largest
        level a later *identical* prompt could still hit — and prefill
        chunks are clipped to land exactly on it."""
        pc = self._pc
        if not pc.sharing:
            return
        L = len(slot.feed)
        if pc.has_state:
            cap = slot.req.prefix_len if slot.req.prefix_len else L - 1
            reg = (min(cap, L) // pc.ps) * pc.ps
        else:
            reg = (L // pc.ps) * pc.ps
        covered = hit.length if hit else 0
        if reg > covered and reg > slot.pos:
            slot.reg_at = reg

    # -- scheduling ------------------------------------------------------------

    def _is_float(self, slot: _Slot) -> bool:
        """Ladder rung 2+: this request's steps run the float-activation
        trace (resilience.DEGRADE_LADDER)."""
        return (slot.req is not None
                and slot.req.degrade_level >= len(rsl.DEGRADE_LADDER))

    def _pick_mode(self) -> tuple[bool, bool]:
        """(float_mode, partitioned): which activation trace this iteration
        steps, and whether BOTH kinds of row are active.  Partitioned ticks
        alternate between the two sets — rung-2 rows never share a batch
        with rung-0/1 rows, so degrading one request cannot perturb the
        tokens of any other (the int8 and float traces are separate jitted
        programs; a row's logits depend only on its own cache row, but the
        trace choice is batch-global)."""
        has_f = any(self._is_float(s) for s in self.slots)
        has_n = any(s.req is not None and not self._is_float(s)
                    for s in self.slots)
        if has_f and has_n:
            self._serve_float = not self._serve_float
            return self._serve_float, True
        return has_f, False

    def _schedule(self, float_mode: bool = False) -> np.ndarray:
        """Token-budget pass: decodes first (1 token each, latency), then
        prefills split the remaining budget into ≤chunk_size chunks.  Slots
        are visited in round-robin order so a budget tighter than the active
        slot count rotates starvation instead of pinning it to high slots.
        Only rows matching ``float_mode`` (degradation rung 2+ vs below) are
        scheduled — the two activation traces never share a batch."""
        n = np.zeros((self.B,), np.int32)
        budget = self.token_budget
        order = [(b + self._rr) % self.B for b in range(self.B)]
        self._rr = (self._rr + 1) % self.B
        for b in order:
            slot = self.slots[b]
            if (slot.req is not None and not slot.to_feed and budget > 0
                    and self._is_float(slot) == float_mode):
                n[b] = 1
                budget -= 1
        for b in order:
            slot = self.slots[b]
            if (slot.req is None or not slot.to_feed
                    or self._is_float(slot) != float_mode):
                continue
            room = self.max_len - 1 - slot.pos  # leave headroom to sample
            take = min(len(slot.to_feed), self.chunk, budget, max(room, 0))
            if (slot.reg_at is not None and self._pc.has_state
                    and slot.pos < slot.reg_at):
                # land a chunk boundary exactly on the registration point so
                # the state snapshot corresponds to the registered tokens
                take = min(take, slot.reg_at - slot.pos)
            n[b] = take
            budget -= take
        return n

    def _alloc(self, n: np.ndarray) -> list:
        """Allocate pool pages for every scheduled row's write window,
        escalating on a dry pool: evict cold prefix entries → preempt a
        strictly-lower-priority victim → shrink the prefill take → as a
        last resort preempt the row itself (or capacity-finish it when it
        is the only active request and the empty pool still cannot hold
        it).  Returns per-slot (fresh, triples) plans."""
        pc = self._pc
        plans = [([], []) for _ in range(self.B)]
        allocated: set[int] = set()
        for b in range(self.B):
            slot = self.slots[b]
            if slot.req is None or n[b] == 0:
                continue
            while True:
                plan = pc.plan_writes(b, slot.pos, int(n[b]))
                if plan is not None:
                    plans[b] = plan
                    allocated.add(b)
                    break
                if pc.evict_one(require_free=True):
                    continue
                v = self._victim(self._prio(slot.req), allocated | {b})
                if v is not None:
                    if n[v]:
                        n[v] = 0
                    self._preempt(v)
                    continue
                take = pc.max_take(b, slot.pos)
                if slot.to_feed and take > 0:
                    n[b] = min(int(n[b]), take)
                    continue
                if sum(1 for s in self.slots if s.req is not None) == 1:
                    # the whole pool is free for this one request and its
                    # next token still does not fit: genuine capacity end
                    self._finish_slot(b, "capacity")
                else:
                    self._preempt(b)
                n[b] = 0
                break
        return plans

    def _pack_plans(self, plans: list):
        """Flatten per-slot page plans into the bucketed device operands:
        fresh page ids (pad: n_pages → reset drops them) and write-window
        (row, logical, physical) triples (pad: phys=n_pages → scatter
        drops them)."""
        pc = self._pc
        fresh = [p for f, _ in plans for p in f]
        triples = [t for _, ts in plans for t in ts]
        F = _bucket(max(len(fresh), 1))
        M = _bucket(max(len(triples), 1))
        fresh_a = np.full((F,), pc.n_pages, np.int32)
        fresh_a[:len(fresh)] = fresh
        rows = np.zeros((M,), np.int32)
        lps = np.zeros((M,), np.int32)
        phys = np.full((M,), pc.n_pages, np.int32)
        for i, (r, lp, p) in enumerate(triples):
            rows[i], lps[i], phys[i] = r, lp, p
        return (jnp.asarray(fresh_a), jnp.asarray(rows), jnp.asarray(lps),
                jnp.asarray(phys))

    def _advance(self, finished: list[Request]):
        float_mode, partitioned = self._pick_mode()
        n = self._schedule(float_mode)
        if not n.any() and partitioned:
            # the selected set had no headroom this tick; try the other one
            float_mode = not float_mode
            n = self._schedule(float_mode)
        plans = None
        if self._pc is not None:
            plans = self._alloc(n)
        if not n.any():  # every active slot is out of cache headroom
            for b, slot in enumerate(self.slots):
                if slot.req is not None:
                    self._finish_slot(b, "capacity")
            return
        C = _bucket(int(n.max()))
        tokens = np.zeros((self.B, C), np.int32)
        steps = np.zeros((self.B,), np.int32)
        sampling = [False] * self.B
        prompt_toks = 0
        decode_toks = 0
        for b, slot in enumerate(self.slots):
            if slot.req is None or n[b] == 0:
                continue
            steps[b] = slot.pos
            if slot.to_feed:
                prompt_toks += int(n[b])
                for i in range(n[b]):
                    tokens[b, i] = slot.to_feed.popleft()
                sampling[b] = len(slot.to_feed) == 0  # chunk holds prompt end
            else:
                decode_toks += 1
                tokens[b, 0] = slot.req.output[-1]
                sampling[b] = True
        self._iter += 1
        sched_uids = [self.slots[b].req.uid for b in range(self.B)
                      if self.slots[b].req is not None and n[b]]
        self._last_stepped = set(sched_uids)
        t0 = time.perf_counter()
        self._step_inflight_since = time.monotonic()   # watchdog window opens
        self._poll_faults_pre(sched_uids)
        if self._pc is not None:
            pc = self._pc
            fresh, rows, lps, phys = self._pack_plans(plans)
            pstep = (self._float_paged_step() if float_mode
                     else self._paged_step)
            logits, pool, static = pstep(
                self.params, tuple(pc.pool), tuple(pc.static),
                jnp.asarray(pc.tables), fresh, rows, lps, phys,
                jnp.asarray(tokens), jnp.asarray(steps), jnp.asarray(n))
            pc.pool, pc.static = list(pool), list(static)
        else:
            step = self._float_plain_step() if float_mode else self._step
            logits, self.cache = step(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(steps), jnp.asarray(n))
        if self.spec_k and not float_mode:
            # keep the draft cache in sync through prefill / non-greedy
            # iterations: replay the same chunk through the draft model.
            # Float-mode ticks skip the replay: only rung-2 rows are
            # scheduled then, and a rung≥1 request never drafts again.
            _, self.draft_cache = self._step(
                self.draft_params, self.draft_cache, jnp.asarray(tokens),
                jnp.asarray(steps), jnp.asarray(n))
        logits = jax.block_until_ready(logits)
        self._step_inflight_since = None
        dt = time.perf_counter() - t0
        self.stats["steps"] += 1
        self.stats["prefill_tokens"] += prompt_toks
        self.stats["decode_tokens"] += decode_toks
        self.stats["step_s"].append(dt)
        if prompt_toks == 0 and decode_toks > 0:
            self.stats["decode_step_s"].append(dt)
        # mixed steps: split the iteration's wall time across the phases in
        # proportion to the tokens each fed (an all-or-nothing attribution
        # inflates the minority phase's tok/s)
        total = prompt_toks + decode_toks
        if total:
            self.stats["prefill_time"] += dt * prompt_toks / total
            self.stats["decode_time"] += dt * decode_toks / total
        self._note_step_done(dt)
        ok = self._row_health(logits, sched_uids)
        self.key, sub = jax.random.split(self.key)
        # logits: (B, 1, V) — the model's head already projected each row's
        # final live column only
        greedy = np.asarray(jnp.argmax(logits[:, 0], axis=-1))  # (B,)
        for b, slot in enumerate(self.slots):
            if slot.req is None or n[b] == 0:
                continue
            if ok is not None and not bool(ok[b]):
                # guardrail trip: requeue BEFORE advancing pos, registering
                # the prefix or sampling — a poisoned row never contributes
                # shared pages and never emits a garbage token; its cache
                # rebuilds from tokens on resume at the next ladder rung
                self._numeric_trip(b)
                continue
            slot.pos += int(n[b])
            if slot.reg_at is not None and slot.pos >= slot.reg_at:
                self._register(b, slot)
            if not sampling[b]:
                continue
            if slot.req.temperature > 0:
                kb = jax.random.fold_in(sub, b)
                nxt = int(jax.random.categorical(
                    kb, logits[b, 0] / slot.req.temperature))
            else:
                nxt = int(greedy[b])
            self._emit(slot.req, nxt)
            if (len(slot.req.output) >= slot.req.max_new_tokens
                    or slot.pos >= self.max_len - 1):
                self._finish_slot(
                    b, "length"
                    if len(slot.req.output) >= slot.req.max_new_tokens
                    else "capacity")

    def _emit(self, req: Request, tok: int):
        if not req.output:
            req.t_first = time.perf_counter()
        req.output.append(tok)

    def _register(self, b: int, slot: _Slot):
        pc = self._pc
        if pc.has_state:
            pc.register_prefix(b, slot.feed, slot.reg_at)
        else:
            pc.register_levels(b, slot.feed, slot.reg_at)
        slot.reg_at = None

    def _advance_spec(self, finished: list[Request]):
        """One draft-verify round (every active slot greedy-decoding).

        Round protocol, per row at cache length P with pending token t0
        (the last sampled output, not yet fed):

          draft   k C=1 steps of the truncated model on a throwaway copy of
                  the draft cache → d_1..d_k
          verify  ONE full-model chunk over [t0, d_1..d_k] at steps=P with
                  all_logits: column i's argmax g_i is exactly what plain
                  decode would sample after committing t0..d_i
          accept  longest prefix with d_{i+1} == g_i, plus the bonus g_n —
                  n_acc+1 tokens per round, ≥1 always
          commit  roll the full cache back to the n_comm = emitted committed
                  tokens (bit-exact), then resync the authoritative draft
                  cache with one draft chunk over the same buffer at
                  n_tokens = n_comm (dead columns are exact no-ops)

        The whole round is ONE jitted dispatch (``_make_spec_round``); only
        the tiny drafted/accepted token ids come back to the host.

        Paged mode allocates each live row's worst-case write window
        (min(k+1, budget) tokens) up front; if the pool cannot hold a
        window even after eviction/preemption, the iteration falls back to
        the plain path (which can shrink to one token or preempt).  After
        the commit, pages past the new length return to the pool — the
        rollback already rewound their contents in the view, so nothing
        stale is ever scattered."""
        k = self.spec_k
        B = self.B
        steps = np.zeros((B,), np.int32)
        live = np.zeros((B,), np.int32)
        cur = np.zeros((B,), np.int32)
        budget = np.zeros((B,), np.int32)
        for b, slot in enumerate(self.slots):
            if slot.req is not None:
                steps[b] = slot.pos
                live[b] = 1
                cur[b] = slot.req.output[-1]
                # clamp the round's emission to the request budget and the
                # cache headroom (both ≥ 1 for a scheduled decode row)
                budget[b] = min(
                    slot.req.max_new_tokens - len(slot.req.output),
                    (self.max_len - 1) - slot.pos)
        plans = None
        if self._pc is not None:
            plans = self._alloc_spec(live, steps, budget)
            if plans is None:
                self._advance(finished)   # pool pressure: plain path handles
                return
        self._iter += 1
        sched_uids = [self.slots[b].req.uid for b in range(self.B)
                      if self.slots[b].req is not None and live[b]]
        self._last_stepped = set(sched_uids)
        t0 = time.perf_counter()
        self._step_inflight_since = time.monotonic()   # watchdog window opens
        self._poll_faults_pre(sched_uids)
        if self._pc is not None:
            pc = self._pc
            fresh, rows, lps, phys = self._pack_plans(plans)
            (pool, static, self.draft_cache, draft_toks, greedy, n_acc,
             n_comm, ok) = self._paged_spec(
                self.params, self.draft_params, tuple(pc.pool),
                tuple(pc.static), self.draft_cache, jnp.asarray(pc.tables),
                fresh, rows, lps, phys, jnp.asarray(cur), jnp.asarray(steps),
                jnp.asarray(live), jnp.asarray(budget))
            pc.pool, pc.static = list(pool), list(static)
            sync_root = pc.pool[0] if pc.pool else pc.static[0]
        else:
            (self.cache, self.draft_cache, draft_toks, greedy, n_acc,
             n_comm, ok) = self._spec_round(
                self.params, self.draft_params, self.cache, self.draft_cache,
                jnp.asarray(cur), jnp.asarray(steps), jnp.asarray(live),
                jnp.asarray(budget))
            sync_root = self.cache
        draft_toks = np.asarray(draft_toks)
        greedy = np.asarray(greedy)
        n_acc = np.asarray(n_acc)
        n_comm = np.asarray(n_comm)
        jax.block_until_ready(sync_root)
        self._step_inflight_since = None
        dt = time.perf_counter() - t0
        self._note_step_done(dt)
        # verify-logit health came back with the round (one fused dispatch);
        # a tripped row walks the ladder instead of emitting garbage
        if self._guard is not None:
            okv = self._inject_nan(np.asarray(ok).astype(bool), sched_uids)
        else:
            okv = np.ones((self.B,), bool)
        good = live.astype(bool) & okv
        n_live = int(live.sum())
        total_emitted = int(n_comm[good].sum())
        self.stats["steps"] += 1
        self.stats["decode_tokens"] += total_emitted
        self.stats["decode_time"] += dt
        self.stats["step_s"].append(dt)
        self.stats["decode_step_s"].append(dt)
        self.stats["spec_rounds"] += 1
        self.stats["spec_drafted"] += k * n_live
        self.stats["spec_accepted"] += int(np.sum(n_acc[good]))
        self.stats["spec_emitted"] += total_emitted
        for b, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            if live[b] and not okv[b]:
                # a poisoned verify round commits nothing for this row: the
                # request requeues a rung further down the ladder and its
                # pages (incl. the round's window) free with the slot
                self._numeric_trip(b)
                continue
            # emitted tokens: the accepted draft prefix, plus the bonus
            # (verify's next-token at the first mismatch) when it fit
            emit = int(n_comm[b])
            toks = [int(draft_toks[b, j])
                    for j in range(min(emit, int(n_acc[b])))]
            if emit == int(n_acc[b]) + 1:
                toks.append(int(greedy[b, n_acc[b]]))
            for t in toks:
                self._emit(slot.req, t)
            slot.pos += emit
            if self._pc is not None:
                # pages allocated for the round's window but not committed
                self._pc.free_beyond(b, slot.pos)
            if (len(slot.req.output) >= slot.req.max_new_tokens
                    or slot.pos >= self.max_len - 1):
                self._finish_slot(
                    b, "length"
                    if len(slot.req.output) >= slot.req.max_new_tokens
                    else "capacity")

    def _alloc_spec(self, live, steps, budget) -> list | None:
        """Allocate each live row's speculative write window.  Returns None
        (after rolling back every allocation made here) when the pool
        cannot hold some window — the caller falls back to plain decode
        for this iteration."""
        pc = self._pc
        plans = [([], []) for _ in range(self.B)]
        allocated: set[int] = set()
        for b in range(self.B):
            if not live[b]:
                continue
            window = min(self.spec_k + 1, int(budget[b]))
            while True:
                plan = pc.plan_writes(b, int(steps[b]), window)
                if plan is not None:
                    plans[b] = plan
                    allocated.add(b)
                    break
                if pc.evict_one(require_free=True):
                    continue
                v = self._victim(self._prio(self.slots[b].req),
                                 allocated | {b})
                if v is not None:
                    self._preempt(v)
                    live[v] = 0
                    continue
                for ob in allocated:   # roll back: stale never-reset pages
                    for p in plans[ob][0]:
                        pc.pages.deref(p)
                        pc.tables[ob, np.where(pc.tables[ob] == p)[0]] = 0
                return None
        return plans
