"""Continuous-batching inference engine.

A fixed pool of B slots advances in lockstep through one jitted
``decode_step`` per iteration; each slot carries its own position counter
(the (B,)-step support in the attention/MLA caches), so requests of
different lengths coexist and a finished slot is immediately recycled for
the next queued request — no batch drain, the production serving pattern.

Prompt ingestion is token-at-a-time through the same decode path (correct
for every mixer family, incl. recurrent ones).  Sampling: greedy or
temperature.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Request | None = None
    pos: int = 0            # next absolute position to write
    to_feed: deque = dataclasses.field(default_factory=deque)  # prompt left


class Engine:
    def __init__(self, model, params, *, batch_slots: int = 4,
                 max_len: int = 512, seed: int = 0, step_fn=None):
        """``step_fn``: optionally share one jitted decode_step across
        engines (avoids per-engine retrace/compile)."""
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.cache = model.init_cache(batch_slots, max_len)
        self._template = self.cache  # pristine zero cache (reset source)
        # per-leaf batch-axis position (stacked layer caches carry a leading
        # "layers" axis, so batch is NOT uniformly axis 0)
        axes = model.cache_axes()
        is_axes = lambda x: x is None or (isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
        self._batch_axis = jax.tree.map(
            lambda ax: ax.index("batch"), axes, is_leaf=is_axes)
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: deque[Request] = deque()
        self.key = jax.random.PRNGKey(seed)
        self._step = step_fn if step_fn is not None else jax.jit(model.decode_step)

    # -- public ---------------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_iters: int = 10_000) -> list[Request]:
        """Drive until queue + slots drain.  Returns completed requests."""
        finished: list[Request] = []
        for _ in range(max_iters):
            self._admit()
            if not any(s.req for s in self.slots):
                if not self.queue:
                    break
                continue
            self._advance(finished)
        return finished

    # -- internals --------------------------------------------------------------

    def _reset_slot(self, b: int):
        def reset(bax, c, t):
            idx = (slice(None),) * bax + (b,)
            return c.at[idx].set(t[idx])
        self.cache = jax.tree.map(reset, self._batch_axis, self.cache,
                                  self._template)

    def _admit(self):
        for b, slot in enumerate(self.slots):
            if slot.req is None and self.queue:
                req = self.queue.popleft()
                self._reset_slot(b)
                slot.req = req
                slot.pos = 0
                slot.to_feed = deque(req.prompt)

    def _advance(self, finished: list[Request]):
        tokens = np.zeros((self.B, 1), np.int32)
        steps = np.zeros((self.B,), np.int32)
        sampling = [False] * self.B
        for b, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            if slot.to_feed:
                tokens[b, 0] = slot.to_feed.popleft()
                sampling[b] = len(slot.to_feed) == 0  # last prompt token
            else:
                tokens[b, 0] = slot.req.output[-1]
                sampling[b] = True
            steps[b] = slot.pos
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(steps))
        logits = logits[:, -1, :]
        self.key, sub = jax.random.split(self.key)
        greedy = jnp.argmax(logits, axis=-1)
        for b, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            slot.pos += 1
            if not sampling[b]:
                continue
            if slot.req.temperature > 0:
                kb = jax.random.fold_in(sub, b)
                nxt = int(jax.random.categorical(
                    kb, logits[b] / slot.req.temperature))
            else:
                nxt = int(greedy[b])
            slot.req.output.append(nxt)
            if (len(slot.req.output) >= slot.req.max_new_tokens
                    or slot.pos >= self.max_len - 1):
                slot.req.done = True
                finished.append(slot.req)
                slot.req = None
