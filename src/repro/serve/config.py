"""Engine API v2 configuration: grouped sub-configs instead of 13 kwargs.

``Engine(model, params, config: EngineConfig)`` replaces the flat-kwarg
constructor that accreted one knob per PR.  Each sub-config groups the
knobs that move together:

  * ``SchedulerConfig`` — slot count, chunk size, token budget, admission
    policy (``priority`` honors ``Request.priority``; ``fifo`` is the
    arrival-order baseline the serving benchmark compares against).
  * ``MemoryConfig``   — cache geometry: ``max_len`` per request, and the
    paged-allocator knobs (``paged``, ``pages``, ``page_size``,
    ``prefix_sharing``, ``snap_slots``) from serve/paged.py.
  * ``SpeculativeConfig`` — self-speculative draft depth + rank fraction.
  * ``AutotuneConfig`` — BLAST kernel tiling cache warm-at-build.
  * ``quant`` — a ``repro.quant.QuantConfig`` override (weights +
    activations; the cache codec is a model-construction knob).
    ``quant.activations="int8"`` flips the process-wide activation mode at
    engine build, so quantized blast applies compiled afterwards run the
    integer W8A8/W4A8 kernels.

``SamplingParams`` carries the per-request sampling knobs for the v2
``generate()`` / ``generate_batch()`` entry points.

The legacy constructor keeps working through ``EngineConfig.from_legacy``
(the Engine warns once per process); migrate call sites with the table in
serve/README.md.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs for ``Engine.generate*``."""
    max_new_tokens: int = 32
    temperature: float = 0.0


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    slots: int = 4              # concurrent batch rows
    chunk_size: int = 32        # max prompt tokens one slot ingests per step
    token_budget: int | None = None   # max tokens per mixed batch (None: slots*chunk)
    policy: str = "priority"    # "priority" | "fifo" admission order
    deadline_s: float | None = None   # end-to-end per-request deadline
    #                            (submit → done, survives preemption;
    #                            Request.deadline_s overrides per request)


@dataclasses.dataclass(frozen=True)
class MemoryConfig:
    max_len: int = 512          # per-request cache capacity (tokens)
    paged: bool = False         # paged block allocator (serve/paged.py)
    page_size: int = 16         # tokens per KV page (must divide max_len)
    pages: int | None = None    # pool size in pages (None: slots*max_len/page_size)
    prefix_sharing: bool = True # share page-aligned prompt prefixes (paged only)
    snap_slots: int | None = None  # recurrent-state snapshot slots (None: pages//4)


@dataclasses.dataclass(frozen=True)
class SpeculativeConfig:
    k: int = 0                  # draft tokens per round (0 = off)
    draft_rank_frac: float = 0.5


@dataclasses.dataclass(frozen=True)
class AutotuneConfig:
    enabled: bool = False
    cache_path: str | None = None


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Failure-handling knobs (serve/resilience.py, serve/faults.py).

    The guardrail checks every step's logits with one jitted per-row
    reduction; a tripped row walks the degradation ladder (speculative off
    → activation quant off → ``numeric_error``) through the deterministic
    requeue/recompute path.  The watchdog marks the engine ``degraded``
    when a step overruns ``watchdog_deadline_s`` (hung compile/dispatch).
    ``queue_high_water`` bounds queue depth by shedding the lowest-priority
    newest queued work (``stop_reason="shed"``); the HTTP frontend turns
    the same signal into 429 + ``Retry-After`` before admission.
    ``fault_spec`` arms a deterministic ``FaultPlan``
    (serve/faults.py grammar, e.g. ``"nan@6:u3;raise@12:u1;slow@20:0.5"``).
    """
    guardrails: bool = True           # jitted per-row logit health check
    logit_absmax: float = 1e6         # guardrail |logit| trip threshold
    watchdog_deadline_s: float | None = None  # None = watchdog off
    queue_high_water: int | None = None       # shed above this queue depth
    step_error_limit: int = 8         # error-requeues before a request fails
    heartbeat_s: float | None = 10.0  # SSE heartbeat interval (None = off)
    retry_after_base_s: float = 0.5   # 429/503 backoff base
    retry_after_cap_s: float = 30.0   # 429/503 backoff cap
    fault_spec: str | None = None     # serve/faults.py plan (deterministic)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    memory: MemoryConfig = dataclasses.field(default_factory=MemoryConfig)
    speculative: SpeculativeConfig = dataclasses.field(
        default_factory=SpeculativeConfig)
    autotune: AutotuneConfig = dataclasses.field(default_factory=AutotuneConfig)
    resilience: ResilienceConfig = dataclasses.field(
        default_factory=ResilienceConfig)
    quant: object | None = None   # repro.quant.QuantConfig override (weights)
    seed: int = 0
    prestack: bool = True
    # "dp,tp" mesh declaration (launch/serve.py --mesh).  The engine does
    # not build the mesh itself — the launcher builds it and passes a model
    # constructed with the matching Parallel; this field lets the engine
    # VALIDATE the two agree (and records the shape in reports).  None means
    # "whatever the model carries" (incl. no mesh at all).
    mesh: str | None = None

    @staticmethod
    def from_legacy(*, batch_slots: int = 4, max_len: int = 512, seed: int = 0,
                    chunk_size: int = 32, token_budget: int | None = None,
                    quant=None, autotune: bool = False,
                    autotune_cache: str | None = None, speculative: int = 0,
                    draft_rank_frac: float = 0.5,
                    prestack: bool = True) -> "EngineConfig":
        """Map the pre-v2 flat kwargs onto the grouped config (the
        deprecation shim in ``Engine.__init__`` routes old calls here)."""
        return EngineConfig(
            scheduler=SchedulerConfig(slots=batch_slots, chunk_size=chunk_size,
                                      token_budget=token_budget),
            memory=MemoryConfig(max_len=max_len),
            speculative=SpeculativeConfig(k=speculative,
                                          draft_rank_frac=draft_rank_frac),
            autotune=AutotuneConfig(enabled=autotune, cache_path=autotune_cache),
            quant=quant, seed=seed, prestack=prestack)
