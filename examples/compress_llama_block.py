"""Paper §4.2 at layer scale: compress REAL Llama-7B-shaped weight matrices
with BLAST₁₆ at the paper's exact Table-9 ranks, compare against low-rank /
Monarch / block-diagonal on reconstruction error, then show re-training
(gradient refinement on the factors) improving the fit.

    PYTHONPATH=src python examples/compress_llama_block.py [--small]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import blast
from repro.core.compress import compress_linear, reconstruction_error
from repro.core.factorize import factorize
from repro.core.structures import StructureConfig, make_linear


def synth_weight(key, d_in, d_out, decay=2.0):
    """Realistic spectrum: power-law singular values (what trained weights
    look like), not white noise."""
    k1, k2 = jax.random.split(key)
    r = min(d_in, d_out)
    u, _ = jnp.linalg.qr(jax.random.normal(k1, (d_in, r)))
    v, _ = jnp.linalg.qr(jax.random.normal(k2, (d_out, r)))
    s = jnp.arange(1, r + 1, dtype=jnp.float32) ** -decay
    return (u * s) @ v.T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="1024-dim blocks instead of 4096 (fast CPU run)")
    args = ap.parse_args()
    if args.small:
        d, r_attn, b, steps = 512, 128, 16, 80
    else:
        d, r_attn, b, steps = 4096, 1024, 16, 150   # paper Table 9

    w = synth_weight(jax.random.PRNGKey(0), d, d)
    print(f"[compress] target: {d}×{d} power-law-spectrum weight, "
          f"BLAST b={b} r={r_attn} (paper Table 9 setting)")

    rows = {}
    for kind in ("blast", "low_rank", "monarch", "block_diag"):
        st = StructureConfig(kind=kind, b=b, rank=r_attn if kind != "block_diag"
                             else None, keep_ratio=0.5)
        spec = make_linear(d, d, st)
        t0 = time.time()
        params = compress_linear(w, spec, steps=steps)
        err = reconstruction_error(w, spec, params)
        rows[kind] = err
        print(f"[compress] {kind:10s} ({spec.num_params:,} params): "
              f"rel err {err:.4f}  ({time.time()-t0:.0f}s)")

    assert rows["blast"] <= rows["block_diag"] + 1e-6, \
        "BLAST should beat block-diagonal (paper Tables 3/12)"

    # "re-training": continue Alg-2 refinement with more steps → error drops
    res1 = factorize(w.T, b, r_attn, steps=steps // 2)
    res2 = factorize(w.T, b, r_attn, steps=2 * steps)
    e1 = float(jnp.linalg.norm(blast.to_dense(res1.params) - w.T)
               / jnp.linalg.norm(w))
    e2 = float(jnp.linalg.norm(blast.to_dense(res2.params) - w.T)
               / jnp.linalg.norm(w))
    print(f"[compress] refinement: 60 steps err {e1:.4f} → 240 steps {e2:.4f}")
    assert e2 <= e1 + 1e-6


if __name__ == "__main__":
    main()
