"""Quickstart: the BLAST matrix in 60 lines.

1. Build a BLAST-structured linear and multiply (Algorithm 1).
2. Show the special cases (low-rank ⊂ BLAST, paper §2).
3. Compress a dense matrix with preconditioned factorization (Algorithm 2).
4. Swap a whole model's linears to BLAST via the config system.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blast
from repro.core.factorize import factorize, normalized_error
from repro import configs
from repro.models import build_model


def main():
    key = jax.random.PRNGKey(0)

    # 1 — a 512×512 BLAST matrix with 8×8 blocks, rank 32
    params = blast.init(key, m=512, n=512, b=8, r=32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 512))
    y = blast.matmul(x, params)            # Algorithm 1: 3 dense stages
    dense = blast.to_dense(params)         # materialize A for checking
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ dense.T),
                               rtol=1e-4, atol=1e-4)
    print(f"[1] BLAST matmul == dense A·x  "
          f"({blast.num_params(512, 512, 8, 32):,} params vs "
          f"{512*512:,} dense)")

    # 2 — a low-rank matrix is a BLAST matrix with all-ones coupling
    w_down = jax.random.normal(jax.random.PRNGKey(2), (512, 16))
    w_up = jax.random.normal(jax.random.PRNGKey(3), (16, 512))
    lr_as_blast = blast.from_low_rank(w_down, w_up, b=8)
    np.testing.assert_allclose(
        np.asarray(blast.to_dense(lr_as_blast)),
        np.asarray((w_down @ w_up).T), rtol=1e-4, atol=1e-4)
    print("[2] low-rank ⊂ BLAST (paper §2) verified")

    # 3 — compress a pre-trained dense weight (Algorithm 2, PrecGD)
    target = blast.to_dense(blast.init(jax.random.PRNGKey(4), 256, 256, 16, 8))
    res = factorize(target, b=16, r=16, steps=120, precondition=True)
    err = float(normalized_error(target, res.params))
    print(f"[3] Alg. 2 factorization of a BLAST-16 target: rel err {err:.2e}")

    # 4 — whole-model: smollm-135m with every linear as BLAST at 50%
    cfg = configs.get("smollm-135m").reduced()
    model = build_model(cfg)
    p = model.init(jax.random.PRNGKey(5))
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, 16), 0, cfg.vocab)
    out = model.apply(p, tokens=tokens)
    n = sum(np.prod(l.shape) for l in jax.tree.leaves(p))
    print(f"[4] {cfg.name} (reduced, BLAST linears): logits "
          f"{out.logits.shape}, {int(n):,} params")


if __name__ == "__main__":
    main()
