"""Serve a small BLAST model with batched requests through the
chunked-prefill continuous-batching engine — mixed prompt lengths, prefill
chunks and single-token decodes packed into the same steps, slot recycling,
greedy and temperature sampling.

    PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-130m]
"""

import argparse
import time

import jax

from repro import configs
from repro.models import build_model
from repro.serve import (Engine, EngineConfig, MemoryConfig, Request,
                         SchedulerConfig)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--chunk", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.ARCHS[args.arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, EngineConfig(
        scheduler=SchedulerConfig(slots=args.slots, chunk_size=args.chunk),
        memory=MemoryConfig(max_len=96)))

    key = jax.random.PRNGKey(1)
    for i in range(args.requests):
        plen = 3 + (i * 7) % 11                    # mixed prompt lengths
        toks = jax.random.randint(jax.random.fold_in(key, i), (plen,),
                                  0, cfg.vocab)
        engine.submit(Request(uid=i, prompt=[int(t) for t in toks],
                              max_new_tokens=args.max_new,
                              temperature=0.0 if i % 2 else 0.8))
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.output) for r in done)
    tp = engine.throughput()
    print(f"[serve] {args.arch}: {len(done)} requests / {n_tok} new tokens "
          f"in {dt:.1f}s on {args.slots} slots "
          f"(chunk={args.chunk}, {tp['steps']} steps; "
          f"prefill {tp['prefill_tok_s']:.1f} tok/s, "
          f"decode {tp['decode_tok_s']:.1f} tok/s)")
    for r in sorted(done, key=lambda r: r.uid)[:5]:
        mode = "greedy" if r.temperature == 0 else f"T={r.temperature}"
        print(f"  req {r.uid:2d} [{mode:7s}] prompt {len(r.prompt):2d} toks "
              f"→ {r.output}")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
