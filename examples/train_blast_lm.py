"""End-to-end driver: train a ~100M-param GPT-2-family model with BLAST
weights from scratch for a few hundred steps on the synthetic LM stream,
with checkpointing + restart, grad accumulation and the full production
training stack.  (Paper §4.1 protocol at container scale.)

    PYTHONPATH=src python examples/train_blast_lm.py [--steps 300]
        [--full-size]   # true ~100M config (slower on CPU)
"""

import argparse
import dataclasses
import tempfile

import jax

from repro import configs
from repro.core.structures import StructureConfig
from repro.data import TokenStream
from repro.models import build_model
from repro.optim import adamw, cosine_schedule
from repro.train import Trainer

import numpy as np


class _Data:
    def __init__(self, cfg, batch, seq):
        self.stream = TokenStream(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch)

    def batch(self, step):
        return self.stream.batch(step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    if args.full_size:
        # the paper's GPT-2 (124M dense → ~70M with BLAST_6 at 50%)
        cfg = configs.ARCHS["gpt2-blast"]
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  compute_dtype="float32", remat=False)
    else:
        cfg = configs.ARCHS["gpt2-blast"].reduced(
            vocab=512, d_model=128, n_layers=4, d_ff=512, n_heads=4,
            n_kv_heads=4, head_dim=32)
        cfg = dataclasses.replace(
            cfg, structure=StructureConfig(kind="blast", b=4, keep_ratio=0.5))

    model = build_model(cfg)
    n = sum(np.prod(l.shape) for l in jax.tree.leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"[train] {cfg.name}: {int(n):,} params "
          f"(structure={cfg.structure.kind})")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        data = _Data(cfg, args.batch, args.seq)
        trainer = Trainer(
            model, adamw(cosine_schedule(3e-3, args.steps, 20)), data,
            checkpoint_dir=ckpt_dir, checkpoint_every=100, log_every=20)
        out = trainer.run(args.steps)
        h = out["history"]
        print(f"[train] loss {h[0]:.3f} → {h[-1]:.3f} "
              f"({len(h)} steps, ckpt+restart exercised)")
        assert h[-1] < h[0], "training must reduce loss"


if __name__ == "__main__":
    main()
