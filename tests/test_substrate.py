"""Substrate tests: optimizer, schedules, checkpointing (atomicity, integrity,
elastic restore), deterministic data pipeline, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import TokenStream
from repro.optim import (adamw, constant_schedule, cosine_schedule,
                         linear_schedule, quantize_grads_int8, sgdm)


class TestOptim:
    def test_adamw_minimizes_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array([1.0])}
        opt = adamw(constant_schedule(0.1), weight_decay=0.0)
        state = opt.init(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, m = opt.update(g, state, params)
        assert float(loss(params)) < 1e-3

    def test_weight_decay_only_on_matrices(self):
        params = {"w": jnp.ones((2, 2)), "scale": jnp.ones((2,))}
        opt = adamw(constant_schedule(0.0), weight_decay=0.5)  # lr=0
        state = opt.init(params)
        g = jax.tree.map(jnp.zeros_like, params)
        p2, _, _ = opt.update(g, state, params)
        np.testing.assert_allclose(np.asarray(p2["w"]), np.ones((2, 2)))
        np.testing.assert_allclose(np.asarray(p2["scale"]), np.ones((2,)))

    def test_bf16_state_dtype(self):
        params = {"w": jnp.ones((4, 4))}
        opt = adamw(constant_schedule(1e-2), state_dtype=jnp.bfloat16)
        state = opt.init(params)
        assert state["m"]["w"].dtype == jnp.bfloat16

    def test_clip_norm(self):
        params = {"w": jnp.zeros((2,))}
        opt = adamw(constant_schedule(1.0), clip_norm=1.0, weight_decay=0.0)
        state = opt.init(params)
        g = {"w": jnp.array([1e6, 0.0])}
        p2, _, m = opt.update(g, state, params)
        assert float(m["grad_norm"]) == pytest.approx(1e6)
        assert np.isfinite(np.asarray(p2["w"])).all()

    def test_sgdm(self):
        params = {"w": jnp.array([2.0])}
        opt = sgdm(constant_schedule(0.1))
        state = opt.init(params)
        for _ in range(100):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = opt.update(g, state, params)
        assert abs(float(params["w"][0])) < 1e-2

    def test_schedules(self):
        cos = cosine_schedule(1.0, 100, warmup=10)
        lin = linear_schedule(1.0, 100, warmup=10, lr_end=0.0)
        assert float(cos(jnp.int32(5))) == pytest.approx(0.5)
        assert float(cos(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
        assert float(lin(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
        assert float(lin(jnp.int32(55))) == pytest.approx(0.5)


class TestCheckpoint:
    def _tree(self):
        return {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
                "b": jnp.ones((4,), jnp.bfloat16),
                "count": jnp.int32(7)}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        ckpt.save(str(tmp_path), 3, tree)
        skel = jax.tree.map(lambda x: None if x is None else x, tree)
        out = ckpt.restore(str(tmp_path), 3, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_latest_and_gc(self, tmp_path):
        mgr = ckpt.CheckpointManager(str(tmp_path), keep=2, async_write=False)
        for s in (1, 2, 3):
            mgr.save(s, self._tree())
        assert ckpt.latest_step(str(tmp_path)) == 3
        steps = sorted(os.listdir(tmp_path))
        assert len([s for s in steps if s.startswith("step_")]) == 2

    def test_corruption_detected(self, tmp_path):
        tree = self._tree()
        path = ckpt.save(str(tmp_path), 0, tree)
        victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
        arr = np.load(os.path.join(path, victim))
        np.save(os.path.join(path, victim), arr + 1)
        with pytest.raises(IOError, match="corruption"):
            ckpt.restore(str(tmp_path), 0, tree)

    def test_interrupted_write_is_invisible(self, tmp_path):
        tree = self._tree()
        ckpt.save(str(tmp_path), 1, tree)
        # simulate a writer killed mid-flight: leftover .tmp dir
        os.makedirs(os.path.join(tmp_path, "step_00000002.tmp"))
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_elastic_restore_to_sharding(self, tmp_path):
        """Checkpoint saved unsharded restores onto an explicit sharding."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        tree = {"w": jnp.arange(8.0).reshape(2, 4)}
        ckpt.save(str(tmp_path), 0, tree)
        mesh = jax.make_mesh((1,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        out = ckpt.restore(str(tmp_path), 0, tree, shardings=sh)
        assert out["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))

    def test_async_manager_waits(self, tmp_path):
        mgr = ckpt.CheckpointManager(str(tmp_path), async_write=True)
        mgr.save(5, self._tree())
        mgr.wait()
        assert ckpt.latest_step(str(tmp_path)) == 5


class TestData:
    def test_deterministic_and_shard_consistent(self):
        ts = TokenStream(vocab=97, seq_len=16, global_batch=8, seed=1)
        a = ts.batch(3)["tokens"]
        b = ts.batch(3)["tokens"]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        c = ts.batch(4)["tokens"]
        assert not np.array_equal(np.asarray(a), np.asarray(c))
        # shards are independent slices of the same step
        s0 = ts.batch(3, shard=0, n_shards=2)["tokens"]
        s1 = ts.batch(3, shard=1, n_shards=2)["tokens"]
        assert s0.shape == (4, 17) and s1.shape == (4, 17)
        assert not np.array_equal(np.asarray(s0), np.asarray(s1))

    def test_markov_structure_learnable(self):
        """>= 80% of transitions follow the permutation rule."""
        ts = TokenStream(vocab=31, seq_len=64, global_batch=16, noise=0.1)
        toks = np.asarray(ts.batch(0)["tokens"])
        perm = np.asarray(jax.random.permutation(jax.random.PRNGKey(31), 31))
        follows = perm[toks[:, :-1]] == toks[:, 1:]
        assert follows.mean() > 0.8


class TestGradCompression:
    def test_error_feedback_preserves_sum(self):
        g = {"w": jnp.array([0.301, -0.7002, 0.11, 5.0])}
        err = jax.tree.map(jnp.zeros_like, g)
        total_sent = jnp.zeros(4)
        for _ in range(50):
            sent, err = quantize_grads_int8(g, err)
            total_sent = total_sent + sent["w"]
        # EF guarantees the long-run average equals the true gradient
        np.testing.assert_allclose(np.asarray(total_sent) / 50,
                                   np.asarray(g["w"]), rtol=1e-2, atol=1e-2)

    def test_int8_range(self):
        g = {"w": jnp.array([1e-9, -1e9])}
        sent, err = quantize_grads_int8(g, jax.tree.map(jnp.zeros_like, g))
        assert np.isfinite(np.asarray(sent["w"])).all()
