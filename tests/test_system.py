"""End-to-end behaviour tests for the paper's system: training-from-scratch
with BLAST weights learns the synthetic stream, and tracks dense within a
modest margin at 50% params (paper §4.1 ordering)."""

import dataclasses

import jax
import numpy as np

from repro import configs
from repro.core.structures import StructureConfig
from repro.data import TokenStream
from repro.models import build_model
from repro.optim import adamw, cosine_schedule
from repro.train import Trainer


class _Data:
    def __init__(self, cfg, batch=8, seq=32):
        self.stream = TokenStream(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch)

    def batch(self, step):
        return self.stream.batch(step)


def _train(cfg, steps=60, lr=3e-3):
    model = build_model(cfg)
    trainer = Trainer(model, adamw(cosine_schedule(lr, steps, 5)),
                      _Data(cfg), log_every=100_000)
    out = trainer.run(steps, key=jax.random.PRNGKey(0))
    return float(np.mean(out["history"][-5:]))


def _base():
    return configs.ARCHS["smollm-135m"].reduced(
        vocab=64, d_model=64, n_layers=2, d_ff=128, n_heads=4, n_kv_heads=2)


def test_blast_from_scratch_learns():
    cfg = dataclasses.replace(
        _base(), structure=StructureConfig(kind="blast", b=4, keep_ratio=0.5),
        structure_ffn=None)
    final = _train(cfg)
    assert final < np.log(64) - 0.5, final  # beats uniform entropy


def test_blast_tracks_dense_within_margin():
    dense = dataclasses.replace(_base(), structure=StructureConfig("dense"),
                                structure_ffn=None)
    blast = dataclasses.replace(
        _base(), structure=StructureConfig(kind="blast", b=4, keep_ratio=0.5),
        structure_ffn=None)
    l_dense = _train(dense)
    l_blast = _train(blast)
    # proxy-scale guard: 60-step gap on the 2-layer d=64 proxy is ~0.55
    # nats and shrinking; the paper's equal-or-better claim is at full
    # scale / FLOPs parity.
    assert l_blast < l_dense + 0.75, (l_dense, l_blast)
    assert l_blast < 3.0  # far below the 4.16-nat uniform floor
