"""Tiling-autotuner behavior: cache round-trip, disabled fallback, tuning,
ops integration, and the serving engine's warm-at-build hook."""

import json

import jax
import pytest

from repro.kernels import autotune, ops


@pytest.fixture(autouse=True)
def _clean_state():
    autotune.disable()
    yield
    autotune.disable()


class TestCache:
    def test_round_trip(self, tmp_path):
        path = str(tmp_path / "cache.json")
        key = autotune.Key(T=8, m=64, n=64, b=4, r=16)
        cache = autotune.TuningCache(path)
        assert cache.get(key) is None
        cache.put(key, (8, 32))
        cache.save()
        reloaded = autotune.TuningCache(path)
        assert reloaded.get(key) == (8, 32)

    def test_key_encoding_distinguishes_signatures(self):
        a = autotune.Key(T=8, m=64, n=64, b=4, r=16)
        variants = [
            autotune.Key(T=1, m=64, n=64, b=4, r=16),
            autotune.Key(T=8, m=64, n=64, b=4, r=16, G=2),
            autotune.Key(T=8, m=64, n=64, b=4, r=16, kind="int4"),
            autotune.Key(T=8, m=64, n=64, b=4, r=16, dtype="bfloat16"),
            autotune.Key(T=8, m=64, n=64, b=4, r=16, kind="int8", act="int8"),
        ]
        assert len({k.encode() for k in [a, *variants]}) == 6

    def test_unknown_version_and_garbage_ignored(self, tmp_path):
        p1 = tmp_path / "v999.json"
        p1.write_text(json.dumps({"version": 999, "entries": {"x": [8, 8]}}))
        assert autotune.TuningCache(str(p1)).entries == {}
        p2 = tmp_path / "garbage.json"
        p2.write_text("{not json")
        assert autotune.TuningCache(str(p2)).entries == {}
        p3 = tmp_path / "badvals.json"
        p3.write_text(json.dumps(
            {"version": autotune._VERSION,
             "entries": {"a": [8], "b": [0, 8], "c": [8, 32]}}))
        assert autotune.TuningCache(str(p3)).entries == {"c": (8, 32)}

    def test_version1_cache_migration_ignored(self, tmp_path):
        """Version-1 files predate the activation-storage key component:
        their keys would silently collide with the act="none" twins of
        W8A8/W4A8 calls, so the loader must treat them as empty and let
        re-tuning rebuild the file at the current version."""
        p = tmp_path / "v1.json"
        p.write_text(json.dumps(
            {"version": 1,
             "entries": {"T8.m64.n64.b4.r16.G1.float32.int8.cpu": [8, 32]}}))
        cache = autotune.TuningCache(str(p))
        assert cache.entries == {}
        key = autotune.Key(T=8, m=64, n=64, b=4, r=16, kind="int8")
        cache.put(key, (8, 16))
        cache.save()
        raw = json.loads(p.read_text())
        assert raw["version"] == autotune._VERSION
        assert autotune.TuningCache(str(p)).get(key) == (8, 16)

    def test_missing_file_is_empty(self, tmp_path):
        assert autotune.TuningCache(str(tmp_path / "nope.json")).entries == {}


class TestFallback:
    def test_disabled_lookup_is_none(self):
        assert not autotune.enabled()
        assert autotune.lookup(autotune.Key(T=8, m=64, n=64, b=4, r=16)) is None

    def test_disabled_tune_returns_heuristic(self):
        got = autotune.tune_blast(8, 64, 64, 4, 16)
        assert got == ops.pick_blast_blocks(8, 64, 64, 4, 16, 4, 4)

    def test_resolve_blocks_falls_back_to_heuristic(self):
        import jax.numpy as jnp
        bt, br = ops._resolve_blocks(None, None, 8, 64, 64, 4, 16,
                                     jnp.float32, 4, 1, "float")
        h = ops.pick_blast_blocks(8, 64, 64, 4, 16, 4, 4)
        assert (bt, br) == (min(h[0], 8), min(h[1], 16))

    def test_explicit_blocks_always_win(self):
        import jax.numpy as jnp
        autotune.enable()
        autotune.cache().put(
            autotune.Key(T=8, m=64, n=64, b=4, r=16,
                         backend=jax.default_backend()), (16, 64))
        assert ops._resolve_blocks(8, 8, 8, 64, 64, 4, 16,
                                   jnp.float32, 4, 1, "float") == (8, 8)


class TestTuning:
    def test_tune_caches_a_feasible_candidate(self, tmp_path):
        autotune.enable(str(tmp_path / "c.json"))
        got = autotune.tune_blast(4, 32, 32, 4, 8, reps=1)
        cands = autotune.candidates(4, 32, 32, 4, 8)
        assert got in cands
        key = autotune.Key(T=4, m=32, n=32, b=4, r=8,
                           backend=jax.default_backend())
        assert autotune.cache().get(key) == got
        # second call is a cache hit (no re-timing): identical result
        assert autotune.tune_blast(4, 32, 32, 4, 8, reps=1) == got
        autotune.save()
        assert autotune.TuningCache(str(tmp_path / "c.json")).get(key) == got

    def test_resolve_blocks_uses_tuned_entry(self):
        import jax.numpy as jnp
        autotune.enable()
        key = autotune.Key(T=6, m=32, n=32, b=4, r=8,
                           backend=jax.default_backend())
        autotune.cache().put(key, (8, 8))
        assert ops._resolve_blocks(None, None, 6, 32, 32, 4, 8,
                                   jnp.float32, 4, 1, "float") == (8, 8)

    def test_tuned_blocks_do_not_change_numerics(self, tmp_path):
        import jax.numpy as jnp
        import numpy as np
        from repro.core import blast
        from repro.kernels import ref
        params = blast.init(jax.random.PRNGKey(0), 32, 32, 4, 8)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
        want = ref.blast_matmul_ref(x, params.U, params.S, params.V)
        autotune.enable(str(tmp_path / "c.json"))
        autotune.tune_blast(4, 32, 32, 4, 8, reps=1)
        got = ops.blast_matmul(x, params.U, params.S, params.V,
                               interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)

    def test_candidates_respect_shape_caps(self):
        for bt, br in autotune.candidates(1, 256, 256, 16, 24):
            assert bt <= 8 and br <= 32       # T=1 → 8-row cap; r=24 → 32

    def test_act_tunes_under_distinct_key(self, tmp_path):
        """W8A8 calls key separately from their float-activation twins, and
        the integer-activation path refuses float factors."""
        autotune.enable(str(tmp_path / "c.json"))
        got = autotune.tune_blast(4, 32, 32, 4, 8, kind="int8", act="int8",
                                  reps=1)
        backend = jax.default_backend()
        a8 = autotune.Key(T=4, m=32, n=32, b=4, r=8, kind="int8",
                          backend=backend, act="int8")
        assert autotune.cache().get(a8) == got
        assert autotune.cache().get(
            autotune.Key(T=4, m=32, n=32, b=4, r=8, kind="int8",
                         backend=backend)) is None
        with pytest.raises(ValueError):
            autotune.tune_blast(4, 32, 32, 4, 8, kind="float", act="int8")

    @pytest.mark.parametrize("kind", ["int8", "int4"])
    def test_grouped_act_tuning_runs(self, tmp_path, kind):
        autotune.enable(str(tmp_path / "g.json"))
        got = autotune.tune_blast(4, 32, 32, 4, 8, G=2, kind=kind,
                                  act="int8", reps=1)
        key = autotune.Key(T=4, m=32, n=32, b=4, r=8, G=2, kind=kind,
                           backend=jax.default_backend(), act="int8")
        assert autotune.cache().get(key) == got


class TestEngineWarm:
    def test_engine_build_warms_cache(self, tmp_path):
        from repro import configs
        from repro.models import build_model
        from repro.serve import (AutotuneConfig, Engine, EngineConfig,
                                 MemoryConfig, Request, SchedulerConfig)

        path = str(tmp_path / "engine_cache.json")
        cfg = configs.ARCHS["smollm-135m"].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, EngineConfig(
            scheduler=SchedulerConfig(slots=2, chunk_size=4),
            memory=MemoryConfig(max_len=32),
            autotune=AutotuneConfig(enabled=True, cache_path=path)))
        entries = autotune.TuningCache(path).entries
        assert entries, "warm-at-build must persist tuned tilings"
        # decode width (B) and full-chunk width (B·chunk) both tuned
        assert any(".T2." in k or k.startswith("T2.") for k in entries)
        assert any(k.startswith("T8.") for k in entries)
        eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2))
        done = eng.run()
        assert len(done) == 1 and len(done[0].output) == 2
