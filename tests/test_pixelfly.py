"""Pixelfly block-sparse-butterfly baseline (paper §4.1 comparison)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.structures import (StructureConfig, _pixelfly_blocks,
                                   make_linear)


class TestPixelfly:
    def test_support_pattern(self):
        live = set(_pixelfly_blocks(8))
        assert (0, 0) in live and (0, 1) in live and (0, 2) in live
        assert (0, 4) in live and (0, 3) not in live  # 3 not a power of 2
        # symmetric
        assert all((j, i) in live for i, j in live)

    @pytest.mark.parametrize("d_in,d_out,b", [(32, 32, 4), (64, 32, 8),
                                              (48, 96, 4)])
    def test_shape_and_budget(self, d_in, d_out, b):
        spec = make_linear(d_in, d_out,
                           StructureConfig(kind="pixelfly", b=b,
                                           keep_ratio=0.9))
        params = spec.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (3, d_in))
        y = spec.apply(params, x)
        assert y.shape == (3, d_out)
        assert np.isfinite(np.asarray(y)).all()
        actual = sum(int(np.prod(p.shape)) for p in params.values())
        assert actual == spec.num_params

    def test_matches_dense_scatter_oracle(self):
        """apply == explicit dense matrix with the butterfly mask."""
        d, b = 32, 4
        spec = make_linear(d, d, StructureConfig(kind="pixelfly", b=b,
                                                 keep_ratio=0.3))
        params = spec.init(jax.random.PRNGKey(0))
        q = p = d // b
        dense = np.zeros((d, d), np.float32)
        for e, (i, j) in enumerate(_pixelfly_blocks(b)):
            dense[j * q:(j + 1) * q, i * p:(i + 1) * p] = np.asarray(
                params["w"][e])
        x = jax.random.normal(jax.random.PRNGKey(1), (5, d))
        want = np.asarray(x) @ dense
        if "w_down" in params:
            want = want + np.asarray(
                (x @ params["w_down"]) @ params["w_up"])
        got = np.asarray(spec.apply(params, x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_grad_flows(self):
        spec = make_linear(32, 32, StructureConfig(kind="pixelfly", b=4,
                                                   keep_ratio=0.5))
        params = spec.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32))
        g = jax.grad(lambda p: jnp.sum(spec.apply(p, x) ** 2))(params)
        assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))
        assert float(jnp.sum(jnp.abs(g["w"]))) > 0
