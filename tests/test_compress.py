"""Compression-path tests: per-structure reconstruction quality ordering
(the paper's central empirical claim: BLAST ≥ low-rank ≥ monarch/BD on
structured targets) and Table-9 rank arithmetic."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import blast
from repro.core.compress import compress_linear, reconstruction_error
from repro.core.structures import StructureConfig, make_linear


@pytest.fixture(scope="module")
def mixed_structure_weight():
    """A weight that is low-rank + block-diagonal — the kind of 'mixed'
    structure BLAST captures but pure low-rank / BD do not (paper Fig 2)."""
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    n = 128
    lr = jax.random.normal(k1, (n, 8)) @ jax.random.normal(k2, (8, n)) / 8**0.5
    bd_blocks = jax.random.normal(k3, (8, 16, 16)) / 4.0
    bd = jax.scipy.linalg.block_diag(*[bd_blocks[i] for i in range(8)])
    return lr + bd  # (d_in, d_out)


class TestCompressLinear:
    def test_low_rank_svd_optimal_on_lr_target(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        w = jax.random.normal(k1, (64, 48)) @ jax.random.normal(k2, (48, 64)) / 7.0
        u, s, vt = jnp.linalg.svd(w)
        w = (u[:, :6] * s[:6]) @ vt[:6]  # exact rank 6
        spec = make_linear(64, 64, StructureConfig(kind="low_rank", rank=6))
        params = compress_linear(w, spec)
        assert reconstruction_error(w, spec, params) < 1e-4

    def test_block_diag_exact_on_bd_target(self):
        blocks = jax.random.normal(jax.random.PRNGKey(2), (4, 8, 8))
        w = jax.scipy.linalg.block_diag(*[blocks[i] for i in range(4)])
        spec = make_linear(32, 32, StructureConfig(kind="block_diag", b=4, keep_ratio=0.25))
        params = compress_linear(w, spec)
        assert reconstruction_error(w, spec, params) < 1e-6

    def test_blast_beats_low_rank_on_mixed_target(self, mixed_structure_weight):
        """BLAST captures LR+BD mixtures better than pure LR at equal params
        (paper Fig 1/2 story)."""
        w = mixed_structure_weight
        keep = 0.35
        blast_spec = make_linear(128, 128, StructureConfig(kind="blast", b=8, keep_ratio=keep))
        lr_spec = make_linear(128, 128, StructureConfig(kind="low_rank", keep_ratio=keep))
        assert abs(blast_spec.num_params - lr_spec.num_params) / lr_spec.num_params < 0.1
        e_blast = reconstruction_error(w, blast_spec, compress_linear(w, blast_spec, steps=300))
        e_lr = reconstruction_error(w, lr_spec, compress_linear(w, lr_spec))
        assert e_blast < e_lr, (e_blast, e_lr)

    def test_monarch_fit_reduces_error(self):
        w = jax.random.normal(jax.random.PRNGKey(3), (32, 32))
        spec = make_linear(32, 32, StructureConfig(kind="monarch", b=4, keep_ratio=0.6))
        params = compress_linear(w, spec, steps=400)
        init_err = reconstruction_error(w, spec, spec.init(jax.random.PRNGKey(9)))
        fit_err = reconstruction_error(w, spec, params)
        assert fit_err < 0.9 * init_err


class TestPaperRankArithmetic:
    """Table 9: the published (b, r) choices hit the published CR."""

    @pytest.mark.parametrize(
        "m,n,r,lo,hi",
        [
            (4096, 4096, 1024, 0.49, 0.55),   # Q/K/V/O proj @ 50% CR
            (11008, 4096, 1488, 0.47, 0.55),  # gate/up/down proj @ 50% CR
        ],
    )
    def test_llama_table9(self, m, n, r, lo, hi):
        ratio = blast.num_params(m, n, 16, r) / (m * n)
        assert lo < ratio < hi, ratio
