"""Engine API v2: EngineConfig construction, legacy-kwarg deprecation shim,
async streaming, cancellation, and the HTTP/SSE frontend (serve/http.py)."""

import asyncio
import json
import warnings

import jax
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import (Engine, EngineConfig, MemoryConfig, Request,
                         SamplingParams, SchedulerConfig, SpeculativeConfig)
from repro.serve.http import Server


def _tiny():
    cfg = configs.ARCHS["smollm-135m"].reduced(
        vocab=64, d_model=32, n_layers=2, d_ff=64, n_heads=2, n_kv_heads=1)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _config(**mem):
    return EngineConfig(scheduler=SchedulerConfig(slots=2, chunk_size=8),
                        memory=MemoryConfig(max_len=64, **mem))


class TestEngineConfig:
    def test_legacy_kwargs_warn_once_and_match(self):
        import repro.serve.engine as eng_mod
        model, params = _tiny()
        eng_mod._LEGACY_WARNED = False
        with pytest.warns(DeprecationWarning, match="EngineConfig"):
            legacy = Engine(model, params, batch_slots=2, max_len=64,
                            chunk_size=8)
        # second legacy construction stays silent (warn once per process)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            Engine(model, params, batch_slots=2, max_len=64, chunk_size=8)
        v2 = Engine(model, params, _config())
        prompts = [[4, 5], [7, 8, 9]]
        out_l = [r.output for r in legacy.generate_batch(
            prompts, SamplingParams(max_new_tokens=5))]
        out_2 = [r.output for r in v2.generate_batch(
            prompts, SamplingParams(max_new_tokens=5))]
        assert out_l == out_2

    def test_config_and_legacy_together_raise(self):
        model, params = _tiny()
        with pytest.raises(TypeError, match="not both"):
            Engine(model, params, _config(), batch_slots=2)

    def test_from_legacy_covers_every_knob(self):
        c = EngineConfig.from_legacy(
            batch_slots=3, max_len=96, seed=5, chunk_size=16, token_budget=24,
            speculative=2, draft_rank_frac=0.7, autotune=True,
            autotune_cache="/tmp/x.json", prestack=False)
        assert (c.scheduler.slots, c.scheduler.chunk_size,
                c.scheduler.token_budget) == (3, 16, 24)
        assert c.memory.max_len == 96
        assert (c.speculative.k, c.speculative.draft_rank_frac) == (2, 0.7)
        assert c.autotune.enabled and c.autotune.cache_path == "/tmp/x.json"
        assert c.seed == 5 and c.prestack is False

    def test_configs_are_frozen(self):
        c = _config()
        with pytest.raises(Exception):
            c.scheduler.slots = 9


class TestAsyncGenerate:
    def test_stream_matches_generate_batch(self):
        model, params = _tiny()
        prompts = [[4, 5], [7, 8, 9], [10, 11]]
        ref = [r.output for r in Engine(model, params, _config())
               .generate_batch(prompts, SamplingParams(max_new_tokens=6))]

        async def run():
            eng = Engine(model, params, _config())
            sp = SamplingParams(max_new_tokens=6)

            async def collect(p):
                return [t async for t in eng.generate(p, sp)]

            return await asyncio.gather(*(collect(p) for p in prompts))

        assert asyncio.run(run()) == ref

    def test_close_stream_cancels_and_frees_pages(self):
        model, params = _tiny()
        eng = Engine(model, params, _config(paged=True, page_size=8,
                                            prefix_sharing=False))

        async def run():
            stream = eng.generate([4, 5, 6],
                                  SamplingParams(max_new_tokens=30))
            got = []
            async for tok in stream:
                got.append(tok)
                if len(got) == 2:
                    break              # client walks away mid-generation
            await stream.aclose()
            for _ in range(50):        # driver settles
                if not any(s.req for s in eng.slots):
                    break
                await asyncio.sleep(0.02)
            return got

        got = asyncio.run(run())
        assert len(got) == 2
        eng._pc.audit()
        assert eng._pc.pages.n_free == eng._pc.pages.n_pages - 1
        assert eng.finished[-1].stop_reason == "cancelled"
        assert eng.finished[-1].truncated is False

    def test_cancel_mid_round_resets_speculative_draft(self):
        """Cancelling a slot mid-speculative-decode recycles it cleanly:
        the next occupant's greedy output matches a fresh engine's."""
        model, params = _tiny()
        cfg = EngineConfig(scheduler=SchedulerConfig(slots=1, chunk_size=8),
                           memory=MemoryConfig(max_len=64),
                           speculative=SpeculativeConfig(k=3,
                                                         draft_rank_frac=0.9))
        eng = Engine(model, params, cfg)
        eng.submit(Request(uid=0, prompt=[4, 5, 6], max_new_tokens=40))
        for _ in range(6):             # well into speculative rounds
            eng.tick()
        assert eng.stats["spec_rounds"] > 0
        eng.cancel(0)
        eng.submit(Request(uid=1, prompt=[7, 8, 9], max_new_tokens=6))
        out = {r.uid: r.output for r in eng.run()}
        fresh = Engine(model, params, cfg)
        fresh.submit(Request(uid=1, prompt=[7, 8, 9], max_new_tokens=6))
        assert out[1] == fresh.run()[0].output

    def test_capacity_truncation_sets_flag_preemption_does_not(self):
        model, params = _tiny()
        eng = Engine(model, params, EngineConfig(
            scheduler=SchedulerConfig(slots=1, chunk_size=8),
            memory=MemoryConfig(max_len=16)))
        eng.submit(Request(uid=0, prompt=[4, 5, 6], max_new_tokens=64))
        r = eng.run()[0]
        assert r.truncated and r.stop_reason == "capacity"
        assert len(r.output) == 16 - 3


async def _sse_request(port, payload, *, hangup_after=None):
    """Minimal SSE client; returns parsed events.  ``hangup_after``: close
    the socket after that many token events (client disconnect)."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(payload).encode()
    writer.write(b"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                 b"Content-Length: %d\r\n\r\n%s" % (len(body), body))
    await writer.drain()
    events = []
    try:
        while True:
            line = await asyncio.wait_for(reader.readline(), timeout=60)
            if not line:
                break
            if line.startswith(b"data: "):
                events.append(json.loads(line[6:]))
                if events[-1].get("done"):
                    break
                if hangup_after and len(events) >= hangup_after:
                    break
    finally:
        writer.close()
    return events


async def _get(port, path):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, body = raw.partition(b"\r\n\r\n")
    return head.split(b" ", 2)[1], body


class TestHTTPServer:
    def test_sse_stream_and_metrics(self):
        model, params = _tiny()
        eng = Engine(model, params, _config())
        ref = Engine(model, params, _config()).generate_batch(
            [[4, 5, 6]], SamplingParams(max_new_tokens=5))[0].output

        async def run():
            srv = Server(eng, port=0)
            port = await srv.start()
            events = await _sse_request(
                port, {"prompt": [4, 5, 6], "max_new_tokens": 5})
            status, body = await _get(port, "/v1/metrics")
            health, _ = await _get(port, "/health")
            bad_r, _ = await _get(port, "/nope")
            await srv.stop()
            return events, status, json.loads(body), health, bad_r

        events, status, metrics, health, bad = asyncio.run(run())
        assert [e["token"] for e in events[:-1]] == ref
        assert events[-1] == {"done": True, "stop_reason": "length"}
        assert status == b"200" and health == b"200" and bad == b"404"
        assert metrics["sla"]["classes"]["0"]["requests"] == 1
        assert metrics["active"] == 0 and metrics["queued"] == 0

    def test_mid_stream_disconnect_cancels_request(self):
        model, params = _tiny()
        eng = Engine(model, params, _config(paged=True, page_size=8,
                                            prefix_sharing=False))

        async def run():
            srv = Server(eng, port=0)
            port = await srv.start()
            partial = await _sse_request(
                port, {"prompt": [4, 5, 6], "max_new_tokens": 60},
                hangup_after=2)
            # server notices the hangup on its next token write; a second
            # request proves the engine (and its pages) recovered
            events = await _sse_request(
                port, {"prompt": [7, 8], "max_new_tokens": 4})
            await srv.stop()
            return partial, events

        partial, events = asyncio.run(run())
        assert len(partial) == 2
        assert events[-1] == {"done": True, "stop_reason": "length"}
        assert any(r.stop_reason == "cancelled" for r in eng.finished)
        eng._pc.audit()
        assert eng._pc.pages.n_free == eng._pc.pages.n_pages - 1

    def test_bad_request_rejected(self):
        model, params = _tiny()
        eng = Engine(model, params, _config())

        async def run():
            srv = Server(eng, port=0)
            port = await srv.start()
            reader, writer = await asyncio.open_connection("127.0.0.1", port)
            body = b'{"prompt": []}'
            writer.write(b"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                         b"Content-Length: %d\r\n\r\n%s" % (len(body), body))
            await writer.drain()
            raw = await reader.read()
            writer.close()
            await srv.stop()
            return raw

        raw = asyncio.run(run())
        assert raw.split(b" ", 2)[1] == b"400"
