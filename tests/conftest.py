"""Imported before any test module: installs the JAX compat shims (via
``import repro``) so test-module-level ``from jax.sharding import ...``
bindings pick up the shimmed API on older jax."""

import repro  # noqa: F401
