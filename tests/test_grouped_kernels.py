"""Grouped BLAST kernels, the native int4 nibble path, and the
``group_apply`` fast path — oracle sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant as qt
from repro.core import structures
from repro.core.structures import StructureConfig, make_linear
from repro.kernels import ops, ref


def tol(dtype):
    return (dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16
            else dict(rtol=3e-4, atol=3e-4))


def _rand_group(key, G, b, p, q, r, dtype=jnp.float32):
    ku, ks, kv = jax.random.split(key, 3)
    U = jax.random.normal(ku, (G, b, p, r), dtype=dtype)
    S = jax.random.normal(ks, (G, b, b, r), dtype=dtype)
    V = jax.random.normal(kv, (G, b, q, r), dtype=dtype)
    return U, S, V


def _quantize_group(U, S, V, bits=8):
    Uq = qt.quantize(U, bits=bits, block_axes=(2, 3))
    Sq = qt.quantize(S, bits=bits, block_axes=(3,))
    Vq = qt.quantize(V, bits=bits, block_axes=(2, 3))
    G, b = U.shape[:2]
    return (Uq, Sq, Vq, Uq.scale.reshape(G, b), Sq.scale.reshape(G, b, b),
            Vq.scale.reshape(G, b))


class TestGroupedKernel:
    """`blast_matmul_grouped_pallas` == the per-projection loop."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "G,T,b,p,q,r",
        [
            (2, 16, 4, 8, 6, 8),     # tiny gate+up-like pair
            (3, 8, 4, 16, 16, 24),   # decode-ish T, three sets
            (2, 40, 8, 6, 4, 12),    # unaligned T / r → padding path
            (4, 1, 16, 16, 8, 16),   # T=1 matvec, wide group
        ],
    )
    def test_matches_per_projection_loop(self, G, T, b, p, q, r, dtype):
        key = jax.random.PRNGKey(hash((G, T, b, p, q, r)) % 2**31)
        U, S, V = _rand_group(key, G, b, p, q, r, dtype)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, b * q), dtype=dtype)
        got = ops.blast_matmul_grouped(x, U, S, V, interpret=True)
        loop = jnp.stack([ops.blast_matmul(x, U[g], S[g], V[g],
                                           interpret=True)
                          for g in range(G)])
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(loop, np.float32), **tol(dtype))
        want = ref.blast_matmul_grouped_ref(x, U, S, V)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **tol(dtype))

    def test_batched_leading_dims(self):
        U, S, V = _rand_group(jax.random.PRNGKey(0), 2, 4, 8, 8, 8)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 32))
        got = ops.blast_matmul_grouped(x, U, S, V, interpret=True)
        want = ref.blast_matmul_grouped_ref(x, U, S, V)
        assert got.shape == (2, 2, 5, 32)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("G,T,b,p,q,r", [(2, 16, 4, 8, 6, 8),
                                             (3, 1, 4, 8, 8, 24)])
    def test_int8_matches_per_projection_loop(self, G, T, b, p, q, r):
        key = jax.random.PRNGKey(hash(("q", G, T, b, p, q, r)) % 2**31)
        U, S, V = _rand_group(key, G, b, p, q, r)
        Uq, Sq, Vq, su, ss, sv = _quantize_group(U, S, V)
        x = jax.random.normal(jax.random.PRNGKey(2), (T, b * q))
        got = ops.blast_matmul_grouped_q(x, Uq.q, Sq.q, Vq.q, su, ss, sv,
                                         interpret=True)
        loop = jnp.stack([
            ops.blast_matmul_q(
                x,
                qt.QArray(Uq.q[g], Uq.scale[g], 8),
                qt.QArray(Sq.q[g], Sq.scale[g], 8),
                qt.QArray(Vq.q[g], Vq.scale[g], 8),
                interpret=True)
            for g in range(G)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(loop),
                                   rtol=3e-4, atol=3e-4)
        want = ref.blast_matmul_grouped_q_ref(x, Uq.q, Sq.q, Vq.q, su, ss, sv)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)


class TestInt4Kernel:
    """`blast_matmul_q4_pallas`: packed operands, unpack-in-register."""

    @pytest.mark.parametrize(
        "T,b,p,q,r",
        [
            (8, 4, 8, 8, 16),    # aligned
            (5, 4, 8, 6, 13),    # odd r → pad nibble + pad bytes
            (1, 8, 16, 8, 24),   # decode matvec
        ],
    )
    def test_matches_unpacked_int8_reference(self, T, b, p, q, r):
        key = jax.random.PRNGKey(hash((T, b, p, q, r)) % 2**31)
        U, S, V = (a[0] for a in _rand_group(key, 1, b, p, q, r))
        U4 = qt.quantize(U, bits=4, block_axes=(1, 2))
        S4 = qt.quantize(S, bits=4, block_axes=(2,))
        V4 = qt.quantize(V, bits=4, block_axes=(1, 2))
        x = jax.random.normal(jax.random.PRNGKey(3), (T, b * q))
        got = ops.blast_matmul_q(x, U4, S4, V4, interpret=True)
        # the same int4 codes unpacked to int8 through the reference path
        want = ref.blast_matmul_q_ref(
            x, qt.int_values(U4), qt.int_values(S4), qt.int_values(V4),
            U4.scale.reshape(b), S4.scale.reshape(b, b), V4.scale.reshape(b))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        # and through the int8 *kernel* on identical codes
        as8 = lambda a: qt.QArray(qt.int_values(a), a.scale, 8)
        got8 = ops.blast_matmul_q(x, as8(U4), as8(S4), as8(V4),
                                  interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(got8),
                                   rtol=2e-5, atol=2e-5)

    def test_operands_stay_packed_at_kernel_boundary(self, monkeypatch):
        """int4 factors must reach the pallas_call still nibble-packed:
        uint8 operands with ceil(r_pad/2) bytes — no int8 materialization."""
        T, b, p, q, r = 3, 4, 8, 8, 21  # unique shape → fresh jit trace
        key = jax.random.PRNGKey(0)
        U, S, V = (a[0] for a in _rand_group(key, 1, b, p, q, r))
        U4 = qt.quantize(U, bits=4, block_axes=(1, 2))
        S4 = qt.quantize(S, bits=4, block_axes=(2,))
        V4 = qt.quantize(V, bits=4, block_axes=(1, 2))
        assert U4.q.dtype == jnp.uint8 and U4.q.shape == (b, p, (r + 1) // 2)

        seen = {}
        real = ops.blast_matmul_q4_pallas

        def spy(x, Up, Sp, Vp, su, ss, sv, **kw):
            seen["shapes"] = (Up.shape, Sp.shape, Vp.shape)
            seen["dtypes"] = (Up.dtype, Sp.dtype, Vp.dtype)
            seen["block_r"] = kw["block_r"]
            return real(x, Up, Sp, Vp, su, ss, sv, **kw)

        monkeypatch.setattr(ops, "blast_matmul_q4_pallas", spy)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, b * q))
        y = ops.blast_matmul_q(x, U4, S4, V4, interpret=True)
        assert y.shape == (T, b * p)
        r_pad = ((r + seen["block_r"] - 1) // seen["block_r"]) * seen["block_r"]
        assert seen["shapes"] == ((b, p, r_pad // 2), (b, b, r_pad // 2),
                                  (b, q, r_pad // 2))
        assert all(dt == jnp.uint8 for dt in seen["dtypes"])

    def test_plane_helpers_roundtrip(self):
        v = jnp.arange(-7, 8, dtype=jnp.int8)           # r = 15 (odd)
        packed = qt.pack_int4(v)
        planes = qt.unpack_int4_planes(packed)
        logical = planes[qt.plane_order(15)]
        np.testing.assert_array_equal(np.asarray(logical), np.asarray(v))


class TestGroupedQ4Kernel:
    """`blast_matmul_grouped_q4_pallas`: one launch over G nibble-packed
    member factor sets == the per-member int4 kernel loop."""

    @pytest.mark.parametrize(
        "G,T,b,p,q,r",
        [
            (2, 16, 4, 8, 6, 8),     # gate+up-like pair, aligned r
            (3, 8, 4, 16, 16, 24),   # three sets
            (2, 5, 4, 8, 6, 13),     # odd r → pad nibble + pad bytes
            (4, 1, 8, 16, 8, 16),    # T=1 matvec, wide group
        ],
    )
    def test_matches_per_member_loop(self, G, T, b, p, q, r):
        key = jax.random.PRNGKey(hash(("q4", G, T, b, p, q, r)) % 2**31)
        U, S, V = _rand_group(key, G, b, p, q, r)
        Uq, Sq, Vq, su, ss, sv = _quantize_group(U, S, V, bits=4)
        assert Uq.q.dtype == jnp.uint8          # packed bytes in, packed out
        x = jax.random.normal(jax.random.PRNGKey(2), (T, b * q))
        got = ops.blast_matmul_grouped_q4(x, Uq.q, Sq.q, Vq.q, su, ss, sv,
                                          interpret=True)
        loop = jnp.stack([
            ops.blast_matmul_q(
                x,
                qt.QArray(Uq.q[g], Uq.scale[g], 4, last_dim=r),
                qt.QArray(Sq.q[g], Sq.scale[g], 4, last_dim=r),
                qt.QArray(Vq.q[g], Vq.scale[g], 4, last_dim=r),
                interpret=True)
            for g in range(G)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(loop),
                                   rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("bits", [8, 4])
    @pytest.mark.parametrize("G,T,b,p,q,r", [(2, 16, 4, 8, 6, 8),
                                             (3, 1, 4, 8, 8, 24)])
    def test_grouped_int_activations_match_ref(self, G, T, b, p, q, r, bits):
        """Grouped W8A8/W4A8: the integer-contraction grouped kernels against
        the integer XLA reference on identical codes (tight)."""
        key = jax.random.PRNGKey(hash(("a8", G, T, b, p, q, r, bits)) % 2**31)
        U, S, V = _rand_group(key, G, b, p, q, r)
        Uq, Sq, Vq, su, ss, sv = _quantize_group(U, S, V, bits=bits)
        x = jax.random.normal(jax.random.PRNGKey(2), (T, b * q))
        if bits == 4:
            got = ops.blast_matmul_grouped_q4(x, Uq.q, Sq.q, Vq.q, su, ss, sv,
                                              act="int8", interpret=True)
        else:
            got = ops.blast_matmul_grouped_q(x, Uq.q, Sq.q, Vq.q, su, ss, sv,
                                             act="int8", interpret=True)
        xq, sx = qt.quantize_act(x)
        want = ref.blast_matmul_grouped_a8_ref(
            xq, sx, qt.int_values(Uq), qt.int_values(Sq), qt.int_values(Vq),
            su, ss, sv)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)


class TestGroupApply:
    """structures.group_apply == per-member linear_apply, incl. padding."""

    def _mla_like(self):
        st = StructureConfig(kind="blast", b=4, keep_ratio=0.5)
        # same d_in/b, different d_out and rank → exercises p/r padding
        return make_linear(64, 32, st), make_linear(64, 24, st)

    def test_blast_float_matches_loop(self):
        s1, s2 = self._mla_like()
        p1 = s1.init(jax.random.PRNGKey(0))
        p2 = s2.init(jax.random.PRNGKey(1))
        x = jax.random.normal(jax.random.PRNGKey(2), (3, 5, 64))
        plan = structures.group_plan((s1, s2), (p1, p2))
        assert plan is not None and plan["kind"] == "blast"
        y1, y2 = structures.group_apply((s1, s2), (p1, p2), x, plan=plan)
        assert y1.shape == (3, 5, 32) and y2.shape == (3, 5, 24)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(s1.apply(p1, x)),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(s2.apply(p2, x)),
                                   rtol=2e-5, atol=2e-5)

    def test_blast_int8_matches_loop(self):
        s1, s2 = self._mla_like()
        q1 = s1.quantize(s1.init(jax.random.PRNGKey(0)), 8)
        q2 = s2.quantize(s2.init(jax.random.PRNGKey(1)), 8)
        x = jax.random.normal(jax.random.PRNGKey(2), (7, 64))
        ys = structures.group_apply((s1, s2), (q1, q2), x)
        for y, (s, p) in zip(ys, ((s1, q1), (s2, q2))):
            np.testing.assert_allclose(np.asarray(y),
                                       np.asarray(s.apply_q(p, x)),
                                       rtol=2e-4, atol=2e-4)

    def test_blast_pallas_path_matches(self):
        s1, s2 = self._mla_like()
        p1 = s1.init(jax.random.PRNGKey(0))
        p2 = s2.init(jax.random.PRNGKey(1))
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
        xla = structures.group_apply((s1, s2), (p1, p2), x)
        pal = structures.group_apply((s1, s2), (p1, p2), x, use_pallas=True)
        for a, b_ in zip(xla, pal):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=3e-4, atol=3e-4)

    def test_dense_and_block_diag_groups(self):
        for kind in ("dense", "block_diag"):
            st = StructureConfig(kind=kind, b=4)
            s1, s2 = make_linear(32, 16, st), make_linear(32, 16, st)
            p1 = s1.init(jax.random.PRNGKey(3))
            p2 = s2.init(jax.random.PRNGKey(4))
            x = jax.random.normal(jax.random.PRNGKey(5), (6, 32))
            plan = structures.group_plan((s1, s2), (p1, p2))
            assert plan is not None, kind
            y1, y2 = structures.group_apply((s1, s2), (p1, p2), x, plan=plan)
            np.testing.assert_allclose(np.asarray(y1),
                                       np.asarray(s1.apply(p1, x)),
                                       rtol=2e-5, atol=2e-5)
            np.testing.assert_allclose(np.asarray(y2),
                                       np.asarray(s2.apply(p2, x)),
                                       rtol=2e-5, atol=2e-5)

    def test_quantized_bundle_with_bias_still_groups(self):
        """The float bias leaf (stripped before group_apply) must not make
        a quantized bundle look 'mixed'-storage — RG-LRU's gate_a/gate_x
        carry biases and must keep their grouped launch under int8."""
        from repro.models import layers as L
        st = StructureConfig(kind="block_diag", b=4)
        s1, s2 = make_linear(32, 32, st), make_linear(32, 32, st)
        p1 = L.linear_init(s1, jax.random.PRNGKey(0), jnp.float32, bias=True)
        p2 = L.linear_init(s2, jax.random.PRNGKey(1), jnp.float32, bias=True)
        p1["bias"] = p1["bias"] + 0.5
        q1 = L.linear_quantize(s1, p1, 8)
        q2 = L.linear_quantize(s2, p2, 8)
        assert structures.group_plan((s1, s2), (q1, q2)) is not None
        x = jax.random.normal(jax.random.PRNGKey(2), (3, 32))
        structures.reset_dispatch_count()
        y1, y2 = L.linear_group_apply((s1, s2), (q1, q2), x)
        assert structures.dispatch_count() == 1
        np.testing.assert_allclose(np.asarray(y1),
                                   np.asarray(L.linear_apply(s1, q1, x)),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(y2),
                                   np.asarray(L.linear_apply(s2, q2, x)),
                                   rtol=2e-4, atol=2e-4)

    def test_ineligible_and_disabled(self):
        st = StructureConfig(kind="blast", b=4)
        s1 = make_linear(64, 32, st)
        s2 = make_linear(32, 32, st)          # different d_in
        p1, p2 = s1.init(jax.random.PRNGKey(0)), s2.init(jax.random.PRNGKey(1))
        assert structures.group_plan((s1, s2), (p1, p2)) is None
        s3 = make_linear(64, 24, st)
        p3 = s3.init(jax.random.PRNGKey(2))
        # mixed storage (float + int8) is ineligible
        assert structures.group_plan((s1, s3),
                                     (p1, s3.quantize(p3, 8))) is None
        # all-int4 blast bundles group (grouped nibble-packed kernel)
        plan4 = structures.group_plan((s1, s3), (s1.quantize(p1, 4),
                                                 s3.quantize(p3, 4)))
        assert plan4 is not None and plan4["storage"] == "int4"
        # non-blast int4 bundles group too (codes unpack to int8 at stack
        # time — RG-LRU's block_diag gate pairs keep their grouped launch)
        bd = StructureConfig(kind="block_diag", b=4)
        b1, b2 = make_linear(32, 32, bd), make_linear(32, 32, bd)
        bp1 = b1.quantize(b1.init(jax.random.PRNGKey(5)), 4)
        bp2 = b2.quantize(b2.init(jax.random.PRNGKey(6)), 4)
        bd_plan = structures.group_plan((b1, b2), (bp1, bp2))
        assert bd_plan is not None and bd_plan["storage"] == "int4"
        xb = jax.random.normal(jax.random.PRNGKey(7), (3, 32))
        for got, s, p in zip(
                structures.group_apply((b1, b2), (bp1, bp2), xb,
                                       plan=bd_plan),
                (b1, b2), (bp1, bp2)):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(s.apply_q(p, xb)))
        with structures.grouping(False):
            assert structures.group_plan((s1, s3), (p1, p3)) is None
        assert structures.group_plan((s1, s3), (p1, p3)) is not None

    def test_blast_int4_matches_loop(self):
        """All-int4 bundle: ONE grouped dispatch, numerics match the
        per-member fused apply_q loop."""
        from repro.models import layers as L
        s1, s2 = self._mla_like()
        q1 = s1.quantize(s1.init(jax.random.PRNGKey(0)), 4)
        q2 = s2.quantize(s2.init(jax.random.PRNGKey(1)), 4)
        x = jax.random.normal(jax.random.PRNGKey(2), (7, 64))
        structures.reset_dispatch_count()
        ys = L.linear_group_apply((s1, s2), (q1, q2), x)
        assert structures.dispatch_count() == 1
        for y, (s, p) in zip(ys, ((s1, q1), (s2, q2))):
            np.testing.assert_allclose(np.asarray(y),
                                       np.asarray(s.apply_q(p, x)),
                                       rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("bits", [8, 4])
    def test_int_activation_mode_matches_loop(self, bits):
        """With the process-wide activation mode on, the grouped path and
        the per-member loop agree (both quantize x per token once)."""
        s1, s2 = self._mla_like()
        q1 = s1.quantize(s1.init(jax.random.PRNGKey(0)), bits)
        q2 = s2.quantize(s2.init(jax.random.PRNGKey(1)), bits)
        x = jax.random.normal(jax.random.PRNGKey(2), (5, 64))
        with structures.activations("int8"):
            ys = structures.group_apply((s1, s2), (q1, q2), x)
            for y, (s, p) in zip(ys, ((s1, q1), (s2, q2))):
                np.testing.assert_allclose(np.asarray(y),
                                           np.asarray(s.apply_q(p, x)),
                                           rtol=2e-4, atol=2e-4)

    def test_int4_prestack_keeps_packed_bytes(self):
        """Pre-stacked int4 bundles hold uint8 nibble-pairs, never an int8
        unpacked copy (the memory win must survive prestacking)."""
        s1, s2 = self._mla_like()
        q1 = s1.quantize(s1.init(jax.random.PRNGKey(0)), 4)
        q2 = s2.quantize(s2.init(jax.random.PRNGKey(1)), 4)
        bundle = structures.prestack((s1, s2), (q1, q2))
        assert bundle is not None and bundle.plan["storage"] == "int4"
        for k in ("U", "S", "V"):
            assert bundle.arrays[k].dtype == jnp.uint8
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
        ys = structures.group_apply((s1, s2), (q1, q2), x,
                                    plan=bundle.plan, stacked=bundle.arrays)
        for y, (s, p) in zip(ys, ((s1, q1), (s2, q2))):
            np.testing.assert_allclose(np.asarray(y),
                                       np.asarray(s.apply_q(p, x)),
                                       rtol=2e-4, atol=2e-4)

    def test_dispatch_counter(self):
        from repro.models import layers as L
        st = StructureConfig(kind="blast", b=4)
        s1, s2 = make_linear(64, 32, st), make_linear(64, 32, st)
        p1, p2 = s1.init(jax.random.PRNGKey(0)), s2.init(jax.random.PRNGKey(1))
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 64))
        structures.reset_dispatch_count()
        L.linear_group_apply((s1, s2), (p1, p2), x)
        assert structures.dispatch_count() == 1          # one grouped launch
        with structures.grouping(False):
            structures.reset_dispatch_count()
            L.linear_group_apply((s1, s2), (p1, p2), x)
            assert structures.dispatch_count() == 2      # per-projection loop


class TestPickBlocksTClamp:
    """pick_blast_blocks must budget VMEM for the T it will actually run."""

    def test_decode_t_clamps_block_t(self):
        bt, _ = ops.pick_blast_blocks(1, 4096, 4096, 16, 1024)
        assert bt == 8
        bt, _ = ops.pick_blast_blocks(17, 4096, 4096, 16, 1024)
        assert bt <= 24

    def test_decode_gets_no_smaller_block_r(self):
        # With block_t clamped, the freed VMEM must not shrink block_r:
        # decode tiles deserve at least the prefill pick's r granularity.
        _, br_decode = ops.pick_blast_blocks(1, 8192, 8192, 16, 2048)
        _, br_prefill = ops.pick_blast_blocks(512, 8192, 8192, 16, 2048)
        assert br_decode >= br_prefill
