"""Per-assigned-architecture smoke tests (reduced same-family configs):
one forward + one train step + one decode step on CPU, asserting output
shapes, finite values, and params/axes tree congruence (the sharding-rule
contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.optim import adamw, constant_schedule
from repro.train import make_train_step

ARCHS = list(configs.ASSIGNED)


def _batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0, cfg.vocab)}
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model))
    elif cfg.embeds_input:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
    return batch


def _congruent(params, axes, path=""):
    """Every array leaf must have a same-arity logical-axes tuple."""
    if isinstance(params, dict):
        assert isinstance(axes, dict), f"{path}: axes not dict"
        assert set(params) == set(axes), (
            f"{path}: keys {set(params)} != {set(axes)}")
        for k in params:
            _congruent(params[k], axes[k], f"{path}/{k}")
    elif params is None:
        pass
    else:
        assert isinstance(axes, tuple), f"{path}: axes leaf not tuple"
        assert len(axes) == params.ndim, (
            f"{path}: {len(axes)} axes for ndim {params.ndim}")


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = configs.ARCHS[arch].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1))
        # forward
        if cfg.encoder is not None:
            out = model.apply(params, batch["tokens"][:, :-1], batch["frames"])
        elif cfg.embeds_input:
            out = model.apply(params, embeds=batch["embeds"])
        else:
            out = model.apply(params, tokens=batch["tokens"][:, :-1])
        B = batch["tokens"].shape[0]
        assert out.logits.shape[0] == B and out.logits.shape[-1] == cfg.vocab
        assert np.isfinite(np.asarray(out.logits, np.float32)).all()
        # one train step (fwd+bwd+AdamW) — params stay finite
        opt = adamw(constant_schedule(1e-3))
        step = jax.jit(make_train_step(model, opt))
        opt_state = opt.init(params)
        params2, _, metrics = step(params, opt_state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["skipped"]) == 0.0
        leaves = jax.tree.leaves(params2)
        assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)

    def test_decode_step(self, arch):
        cfg = configs.ARCHS[arch].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, max_len = 2, 32
        if cfg.encoder is not None:
            frames = jax.random.normal(
                jax.random.PRNGKey(2), (B, cfg.encoder.n_frames, cfg.d_model))
            cache = model.init_cache(params, frames, max_len)
        else:
            cache = model.init_cache(B, max_len)
        tok = jnp.ones((B, 1), jnp.int32)
        logits, cache = model.decode_step(params, cache, tok, jnp.int32(0))
        logits, cache = model.decode_step(params, cache, tok, jnp.int32(1))
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_params_axes_congruence(self, arch):
        cfg = configs.ARCHS[arch].reduced()
        model = build_model(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        _congruent(params, model.axes())

    def test_cache_axes_congruence(self, arch):
        cfg = configs.ARCHS[arch].reduced()
        model = build_model(cfg)
        if cfg.encoder is not None:
            params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            frames = jax.ShapeDtypeStruct((2, cfg.encoder.n_frames, cfg.d_model),
                                          jnp.float32)
            cache = jax.eval_shape(
                lambda p, f: model.init_cache(p, f, 16), params, frames)
        else:
            cache = jax.eval_shape(lambda: model.init_cache(2, 16))
        _congruent(cache, model.cache_axes())


class TestFullConfigs:
    """The FULL configs are exercised via eval_shape only (no allocation)."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_full_config_abstract_init(self, arch):
        cfg = configs.ARCHS[arch]
        model = build_model(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n_params = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
        assert n_params > 0
        _congruent(params, model.axes())

    def test_blast_compression_reduces_params(self):
        # BLAST-50% param count < dense for every assigned arch
        for arch in ARCHS:
            dense = configs.get(arch, "dense")
            blast = configs.ARCHS[arch]
            md, mb = build_model(dense), build_model(blast)
            nd = sum(np.prod(l.shape) for l in
                     jax.tree.leaves(jax.eval_shape(md.init, jax.random.PRNGKey(0))))
            nb = sum(np.prod(l.shape) for l in
                     jax.tree.leaves(jax.eval_shape(mb.init, jax.random.PRNGKey(0))))
            assert nb < nd, arch

    def test_variant_registry(self):
        from repro.core.structures import STRUCTURES
        for v in configs.VARIANTS:
            cfg = configs.get("smollm-135m", v)
            assert cfg.structure.kind in STRUCTURES
