"""Self-speculative decoding tests: greedy token-identity of the
draft-verify engine vs plain decode for every mixer family, nested-rank
truncation properties (hypothesis + grid fallback) across float / int8 /
packed-int4 storage, bit-identical cache rollback after rejected drafts
(KV length rewind + SSD / RG-LRU snapshot restore), and the pre-stacked
grouped-projection bundles eliminating per-step stacking work."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro import quant as qt
from repro.core import blast, structures
from repro.core.compress import _svd_low_rank, calibrate_ranks
from repro.core.structures import (StructureConfig, make_linear,
                                   rank_spectrum, truncate_rank)
from repro.models import build_model
from repro.quant import QuantConfig
from repro.serve import (Engine, EngineConfig, MemoryConfig, Request,
                         SchedulerConfig, SpeculativeConfig)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property checks fall back to a parametrized grid
    HAVE_HYPOTHESIS = False


def _family_cfgs():
    return {
        "attn": configs.ARCHS["smollm-135m"].reduced(
            vocab=64, d_model=32, n_layers=2, d_ff=64, n_heads=2,
            n_kv_heads=1),
        "mla": configs.ARCHS["deepseek-v3-671b"].reduced(
            vocab=64, d_model=32, n_layers=2),
        "ssd": configs.ARCHS["mamba2-130m"].reduced(
            vocab=64, d_model=32, n_layers=2),
        "rglru": configs.ARCHS["recurrentgemma-2b"].reduced(
            vocab=64, d_model=32, n_layers=4),
    }


def _prompts(family):
    # rglru's local_attn window=16 (reduced): the 30-token prompt pushes a
    # speculative round across the ring-buffer wrap
    long = list(range(6, 36)) if family == "rglru" else list(range(6, 15))
    return [[4, 5], long, [7, 8, 9]]


def _serve(model, params, k, *, frac=0.9, max_new=(8, 8, 8), family="attn",
           slots=2):
    eng = Engine(model, params, EngineConfig(
        scheduler=SchedulerConfig(slots=slots),
        memory=MemoryConfig(max_len=64),
        speculative=SpeculativeConfig(k=k, draft_rank_frac=frac)))
    for i, p in enumerate(_prompts(family)):
        eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new[i]))
    done = {r.uid: r.output for r in eng.run()}
    return done, eng


# ---- tentpole: speculative greedy decode == plain greedy decode ----------


class TestSpeculativeGreedy:
    @pytest.mark.parametrize("family", ["attn", "mla", "ssd", "rglru"])
    def test_token_identical_to_plain(self, family):
        """Draft-k-verify greedy output is token-for-token identical to
        plain decode for k ∈ {1, 2, 4} on all four cache families (GQA KV,
        MLA latent, SSD state, RG-LRU state + sliding-window ring)."""
        cfg = _family_cfgs()[family]
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        plain, _ = _serve(model, params, 0, family=family)
        for k in (1, 2, 4):
            spec, eng = _serve(model, params, k, family=family)
            assert spec == plain, (family, k, spec, plain)
            assert eng.stats["spec_rounds"] > 0
            # some tokens may flow through the plain path (rounds where
            # speculation isn't eligible), never the other way around
            assert 0 < eng.stats["spec_emitted"] <= eng.stats["decode_tokens"]

    def test_rejection_and_mixed_max_new(self):
        """A heavily truncated draft (frac=0.2) mis-predicts: rejected
        rounds must roll back cleanly and still emit the plain-greedy
        stream, including rows finishing mid-batch at different budgets."""
        cfg = _family_cfgs()["attn"]
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        max_new = (3, 9, 6)  # rows hit their budgets in different rounds
        plain, _ = _serve(model, params, 0, max_new=max_new)
        spec, eng = _serve(model, params, 4, frac=0.2, max_new=max_new)
        assert spec == plain
        # the weak draft actually disagreed with the verifier somewhere —
        # otherwise this test wouldn't cover the rollback path
        assert eng.stats["spec_accepted"] < eng.stats["spec_drafted"]

    def test_k0_degenerates_to_plain_engine(self):
        cfg = _family_cfgs()["attn"]
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        base, eng0 = _serve(model, params, 0)
        assert eng0.stats["spec_rounds"] == 0
        assert eng0.stats["spec_drafted"] == 0
        tp = eng0.throughput()
        assert "acceptance_rate" not in tp
        # default-constructed engine (no speculative kwarg) is the same path
        eng = Engine(model, params, EngineConfig(
            scheduler=SchedulerConfig(slots=2),
            memory=MemoryConfig(max_len=64)))
        for i, p in enumerate(_prompts("attn")):
            eng.submit(Request(uid=i, prompt=list(p), max_new_tokens=8))
        assert {r.uid: r.output for r in eng.run()} == base


# ---- truncate_rank properties (hypothesis + grid fallback) ---------------


def _linear(kind, d, r, seed, bits=None):
    spec = make_linear(d, d, StructureConfig(kind=kind, b=4, rank=r))
    params = spec.init(jax.random.PRNGKey(seed))
    if bits is not None:
        params = spec.quantize(params, bits)
    return spec, params


def _dequant_tree(params):
    return {k: qt.dequantize(v, jnp.float32) if qt.is_qarray(v) else v
            for k, v in params.items()}


def check_full_rank_is_identity(kind, bits, seed):
    """truncate_rank(p, r) with the full rank r is exactly the identity —
    for float, int8 and packed-int4 storage (codes and scales untouched)."""
    _, params = _linear(kind, 16, 8, seed, bits=bits)
    out = truncate_rank(params, structures.linear_rank(params))
    assert set(out) == set(params)
    for k in params:
        a, b = params[k], out[k]
        if qt.is_qarray(a):
            np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q))
            np.testing.assert_array_equal(np.asarray(a.scale),
                                          np.asarray(b.scale))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def check_truncated_apply_equals_zeroed_tail(kind, r_prime, seed):
    """apply(truncate_rank(p, r')) == apply(p with the dropped components
    zeroed): the rank contraction is permutation-invariant, so keeping the
    top-r' columns is the same linear map as zeroing the tail."""
    spec, params = _linear(kind, 16, 8, seed)
    full = structures.linear_rank(params)
    idx = np.sort(np.asarray(
        jax.lax.top_k(rank_spectrum(params), r_prime)[1]))
    dropped = np.setdiff1d(np.arange(full), idx)
    zeroed = dict(params)
    if kind == "blast":
        zeroed["S"] = params["S"].at[:, :, dropped].set(0.0)
    else:
        zeroed["w_down"] = params["w_down"].at[:, dropped].set(0.0)
    x = jax.random.normal(jax.random.PRNGKey(seed + 99), (3, 16))
    y_trunc = spec.apply(truncate_rank(params, r_prime), x)
    y_zero = spec.apply(zeroed, x)
    np.testing.assert_allclose(np.asarray(y_trunc), np.asarray(y_zero),
                               rtol=1e-5, atol=1e-5)


def check_error_monotone_in_rank(kind, seed):
    """Dense reconstruction error is non-increasing in r' on SVD-derived
    factors (orthogonal components with a descending spectrum — the
    regime trained BLAST factors approach)."""
    d, r = 16, 8
    w = jax.random.normal(jax.random.PRNGKey(seed), (d, d))
    if kind == "blast":
        p = blast.from_dense_svd(w, b=4, r=r)
        params = {"U": p.U, "S": p.S, "V": p.V}

        def dense(q):
            return np.asarray(blast.to_dense(
                blast.BlastParams(U=q["U"], S=q["S"], V=q["V"])))
    else:
        params = _svd_low_rank(w, r)

        def dense(q):
            return np.asarray(q["w_down"] @ q["w_up"])
    target = dense(params)
    errs = [float(np.linalg.norm(target - dense(truncate_rank(params, rp))))
            for rp in range(1, r + 1)]
    for lo, hi in zip(errs[1:], errs[:-1]):
        assert lo <= hi + 1e-5, errs
    assert errs[-1] <= 1e-4, errs  # full rank: zero error


def check_truncation_commutes_with_dequant(kind, bits, r_prime, seed):
    """dequantize(truncate_rank(q, r')) is bit-identical to rank-gathering
    dequantize(q): int8 gathers codes, packed int4 unpack-gather-repacks
    losslessly, and per-block scales without a rank extent stay shared."""
    _, qp = _linear(kind, 16, 8, seed, bits=bits)
    full = structures.linear_rank(qp)
    spectrum = rank_spectrum(qp)
    idx = jnp.sort(jax.lax.top_k(spectrum, r_prime)[1])
    tq = _dequant_tree(truncate_rank(qp, r_prime))
    axes = structures._RANK_AXES[kind]
    ref = {k: (structures._gather_rank(v, idx, axes[k], full)
               if k in axes else v)
           for k, v in _dequant_tree(qp).items()}
    for k in ref:
        np.testing.assert_array_equal(np.asarray(tq[k]), np.asarray(ref[k]),
                                      err_msg=f"{kind}/{k} bits={bits}")


def check_passthrough_kinds_untouched(kind, seed):
    """monarch / block_diag / dense have no rank axis: truncate_rank is the
    identity object-wise."""
    spec = make_linear(16, 16, StructureConfig(kind=kind, b=4))
    params = spec.init(jax.random.PRNGKey(seed))
    assert truncate_rank(params, 2) is params


if HAVE_HYPOTHESIS:

    class TestTruncateRankProperties:
        @given(kind=st.sampled_from(["blast", "low_rank"]),
               bits=st.sampled_from([None, 8, 4]),
               seed=st.integers(0, 50))
        @settings(max_examples=12, deadline=None)
        def test_full_rank_identity(self, kind, bits, seed):
            check_full_rank_is_identity(kind, bits, seed)

        @given(kind=st.sampled_from(["blast", "low_rank"]),
               r_prime=st.integers(1, 7), seed=st.integers(0, 50))
        @settings(max_examples=12, deadline=None)
        def test_zeroed_tail_equivalence(self, kind, r_prime, seed):
            check_truncated_apply_equals_zeroed_tail(kind, r_prime, seed)

        @given(kind=st.sampled_from(["blast", "low_rank"]),
               seed=st.integers(0, 50))
        @settings(max_examples=8, deadline=None)
        def test_error_monotone(self, kind, seed):
            check_error_monotone_in_rank(kind, seed)

        @given(kind=st.sampled_from(["blast", "low_rank"]),
               bits=st.sampled_from([8, 4]), r_prime=st.integers(1, 7),
               seed=st.integers(0, 50))
        @settings(max_examples=12, deadline=None)
        def test_quantized_commutes(self, kind, bits, r_prime, seed):
            check_truncation_commutes_with_dequant(kind, bits, r_prime, seed)

else:

    class TestTruncateRankProperties:
        @pytest.mark.parametrize("kind", ["blast", "low_rank"])
        @pytest.mark.parametrize("bits", [None, 8, 4])
        def test_full_rank_identity(self, kind, bits):
            check_full_rank_is_identity(kind, bits, 0)

        @pytest.mark.parametrize("kind", ["blast", "low_rank"])
        @pytest.mark.parametrize("r_prime", [1, 3, 7])
        def test_zeroed_tail_equivalence(self, kind, r_prime):
            check_truncated_apply_equals_zeroed_tail(kind, r_prime, 0)

        @pytest.mark.parametrize("kind", ["blast", "low_rank"])
        def test_error_monotone(self, kind):
            check_error_monotone_in_rank(kind, 0)

        @pytest.mark.parametrize("kind", ["blast", "low_rank"])
        @pytest.mark.parametrize("bits", [8, 4])
        @pytest.mark.parametrize("r_prime", [1, 3, 7])
        def test_quantized_commutes(self, kind, bits, r_prime):
            check_truncation_commutes_with_dequant(kind, bits, r_prime, 0)


class TestTruncatePassthroughAndCalibration:
    @pytest.mark.parametrize("kind", ["monarch", "block_diag", "dense"])
    def test_passthrough_kinds(self, kind):
        check_passthrough_kinds_untouched(kind, 0)

    def test_calibrate_ranks_pooled_share(self):
        spectra = {"a": np.array([8.0, 4.0, 2.0, 1.0]),
                   "b": np.array([100.0, 0.1, 0.1, 0.1])}
        plan = calibrate_ranks(spectra, 1.0)
        assert plan == {"a": 4, "b": 4}
        plan = calibrate_ranks(spectra, 1e-9)
        assert plan == {"a": 1, "b": 1}  # min_rank floor
        # half the pooled rank budget: the flat-spectrum linear keeps more
        # of its rank (3 of 4), the spiky one donates (1 of 4)
        plan = calibrate_ranks(spectra, 0.5)
        assert plan == {"a": 3, "b": 1}

    def test_model_level_plan_and_truncation(self):
        """LM.draft_plan + truncate_params: every planned linear shrinks to
        its calibrated rank, frac=1.0 keeps the full model."""
        cfg = _family_cfgs()["attn"]
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        full_plan = model.draft_plan(params, 1.0)
        assert full_plan and all(r >= 1 for r in full_plan.values())
        half_plan = model.draft_plan(params, 0.5)
        assert sum(half_plan.values()) < sum(full_plan.values())
        dp = model.truncate_params(params, half_plan)
        spectra = jax.jit(model.rank_spectra)(dp)
        for name, r in half_plan.items():
            assert spectra[name].shape[-1] == r, name


# ---- cache rollback: bit-identical to never having drafted ----------------


class TestRollbackBitIdentical:
    @pytest.mark.parametrize("family", ["attn", "mla", "ssd", "rglru"])
    @pytest.mark.parametrize("cache_quant", ["none", "int8"])
    def test_rollback_equals_committing_prefix(self, family, cache_quant):
        """After a verify chunk (collect_states=True), rollback_cache to
        n_comm tokens is BIT-identical to having fed exactly those n_comm
        tokens: KV families by length rewind, SSD / RG-LRU by per-token
        state-snapshot restore.  Rows cover a dead slot (n=0), a mid-chunk
        rejection (n=3) and a fully accepted draft (n=8), quantized caches
        included."""
        cfg = _family_cfgs()[family]
        if cache_quant != "none":
            cfg = dataclasses.replace(
                cfg, quant=QuantConfig(cache=cache_quant))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, L, Cv = 3, 4, 8
        key = jax.random.PRNGKey(7)
        prompt = jax.random.randint(key, (B, L), 0, cfg.vocab)
        cache = model.init_cache(B, 64)
        _, cache0 = model.prefill_chunk(params, cache, prompt,
                                        jnp.zeros((B,), jnp.int32))
        steps = jnp.full((B,), L, jnp.int32)
        vt = jax.random.randint(jax.random.fold_in(key, 1), (B, Cv),
                                0, cfg.vocab)
        n_comm = jnp.array([0, 3, Cv], jnp.int32)
        live = (n_comm > 0).astype(jnp.int32)
        # verify pass over the whole chunk, then rewind to n_comm
        _, verified = model.prefill_chunk(params, cache0, vt, steps,
                                          live * Cv, all_logits=True,
                                          collect_states=True)
        rolled = model.rollback_cache(cache0, verified, steps, n_comm)
        # reference: the same verify program fed ragged n_comm directly (the
        # same static kwargs keep the compiled scan identical — different
        # XLA programs may differ by 1 ulp in fused transcendentals, which
        # would test compiler fusion, not the rollback math)
        _, ref = model.prefill_chunk(params, cache0, vt, steps, n_comm,
                                     all_logits=True, collect_states=True)

        def compare(r, f, path):  # ref carries extra snapshot keys
            if isinstance(r, dict):
                for k in r:
                    compare(r[k], f[k], f"{path}.{k}")
                return
            msg = f"{family}/{cache_quant}{path}"
            if path.endswith("_scale"):
                # int8 codes are bit-identical; the per-row scale (amax/127)
                # is recomputed in a different program context (layer-scan
                # vs rollback vmap) where XLA may fuse the constant division
                # differently — allow exactly 1 float32 ulp there
                np.testing.assert_allclose(
                    np.asarray(r, np.float32), np.asarray(f, np.float32),
                    rtol=1.3e-7, atol=0.0, err_msg=msg)
            else:
                np.testing.assert_array_equal(
                    np.asarray(r), np.asarray(f), err_msg=msg)

        compare(rolled, ref, "")


# ---- pre-stacked grouped-projection bundles -------------------------------


class TestPrestackedBundles:
    def test_prestack_eliminates_per_step_stacking(self):
        """With bundles pre-stacked at load, the per-step grouped apply does
        ZERO pad+stack work (structures.stack_count stays flat) while raw
        params stack every step — and both produce identical outputs."""
        cfg = _family_cfgs()["rglru"]
        cfg = dataclasses.replace(cfg, scan_layers=False)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        pp = model.prestack_params(params)
        tok = jnp.array([[3]], jnp.int32)
        steps = jnp.zeros((1,), jnp.int32)

        def step(p):  # eager: stack/dispatch counters record every call
            cache = model.init_cache(1, 16)
            structures.reset_stack_count()
            structures.reset_dispatch_count()
            lg, _ = model.prefill_chunk(p, cache, tok, steps)
            return lg, structures.stack_count(), structures.dispatch_count()

        lg_raw, stacks_raw, disp_raw = step(params)
        lg_pre, stacks_pre, disp_pre = step(pp)
        assert stacks_raw > 0, "raw params should stack bundles per step"
        assert stacks_pre == 0, "prestacked params must not restack"
        assert disp_pre == disp_raw  # same grouped launches either way
        np.testing.assert_array_equal(np.asarray(lg_raw), np.asarray(lg_pre))

    def test_stale_bundle_is_ignored_not_wrong(self):
        """Quantizing AFTER prestack invalidates the cached float bundles;
        the grouped apply must fall back to stacking (correctness first)
        and match the quantize-only path exactly."""
        cfg = _family_cfgs()["rglru"]
        cfg = dataclasses.replace(cfg, scan_layers=False,
                                  quant=QuantConfig(weights="int8"))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        stale = model.quantize_params(model.prestack_params(params),
                                      cfg.quant)
        clean = model.quantize_params(params, cfg.quant)
        tok = jnp.array([[3]], jnp.int32)
        steps = jnp.zeros((1,), jnp.int32)
        lg_a, _ = model.prefill_chunk(stale, model.init_cache(1, 16), tok,
                                      steps)
        lg_b, _ = model.prefill_chunk(clean, model.init_cache(1, 16), tok,
                                      steps)
        np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))

    def test_engine_spec_round_is_one_dispatch_per_round(self):
        """The fused speculative round costs ONE jitted dispatch (draft scan
        + verify + rollback + draft resync), counted like any other step —
        the engine's per-round step counter increments by exactly 1."""
        cfg = _family_cfgs()["attn"]
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, EngineConfig(
            scheduler=SchedulerConfig(slots=1),
            memory=MemoryConfig(max_len=64),
            speculative=SpeculativeConfig(k=3)))
        eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=9))
        eng.run()
        # steps = prefill chunks + one per speculative round
        assert eng.stats["spec_rounds"] > 0
        prefill_steps = eng.stats["steps"] - eng.stats["spec_rounds"]
        assert prefill_steps >= 1
