"""Paged block allocator: bit-exactness vs slot-static serving, prefix
sharing, refcount invariants, preemption determinism (serve/paged.py)."""

import dataclasses
import random

import jax
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import (Engine, EngineConfig, MemoryConfig, Request,
                         SchedulerConfig, SpeculativeConfig)


def _family_cfgs():
    return {
        "attn": configs.ARCHS["smollm-135m"].reduced(
            vocab=64, d_model=32, n_layers=2, d_ff=64, n_heads=2,
            n_kv_heads=1),
        "mla": configs.ARCHS["deepseek-v3-671b"].reduced(
            vocab=64, d_model=32, n_layers=2),
        "ssd": configs.ARCHS["mamba2-130m"].reduced(
            vocab=64, d_model=32, n_layers=2),
        "rglru": configs.ARCHS["recurrentgemma-2b"].reduced(
            vocab=64, d_model=32, n_layers=4),
    }


def _built(cfg):
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _cfg(*, paged, slots=2, chunk=4, max_len=64, page_size=8, pages=None,
         spec=0, prefix=True):
    return EngineConfig(
        scheduler=SchedulerConfig(slots=slots, chunk_size=chunk),
        memory=MemoryConfig(max_len=max_len, paged=paged, page_size=page_size,
                            pages=pages, prefix_sharing=prefix),
        speculative=SpeculativeConfig(k=spec, draft_rank_frac=0.9))


def _outputs(model, params, config, reqs):
    eng = Engine(model, params, config)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    if eng._pc is not None:
        eng._pc.audit()
    return {r.uid: list(r.output) for r in done}


def _reqs(family):
    long = list(range(6, 36)) if family == "rglru" else list(range(6, 15))
    return [Request(uid=0, prompt=[4, 5], max_new_tokens=6),
            Request(uid=1, prompt=long, max_new_tokens=6),
            Request(uid=2, prompt=[7, 8, 9], max_new_tokens=6)]


class TestPagedExactness:
    """Paged greedy serving is token-for-token identical to slot-static."""

    @pytest.mark.parametrize("family", ["attn", "mla", "ssd", "rglru"])
    def test_matches_slot_static(self, family):
        model, params = _built(_family_cfgs()[family])
        ref = _outputs(model, params, _cfg(paged=False), _reqs(family))
        got = _outputs(model, params, _cfg(paged=True), _reqs(family))
        assert got == ref

    def test_matches_with_int8_cache(self):
        """The pool is ``init_cache`` filtered to sequence-axis leaves, so
        the int8 codec's scale rows page along with the int8 payload."""
        from repro.quant import QuantConfig
        cfg = dataclasses.replace(
            _family_cfgs()["attn"],
            quant=QuantConfig(weights="int8", cache="int8"))
        model, params = _built(cfg)
        ref = _outputs(model, params, _cfg(paged=False), _reqs("attn"))
        got = _outputs(model, params, _cfg(paged=True), _reqs("attn"))
        assert got == ref

    @pytest.mark.parametrize("family", ["attn", "ssd"])
    def test_matches_with_speculative(self, family):
        """Fused draft-verify rounds ride the paged gather/scatter wrapper:
        the round's rollback rewinds the view before the scatter, and
        pages allocated past the committed length are freed again."""
        model, params = _built(_family_cfgs()[family])
        ref = _outputs(model, params, _cfg(paged=False), _reqs(family))
        got = _outputs(model, params, _cfg(paged=True, spec=3),
                       _reqs(family))
        assert got == ref


class TestPrefixSharing:
    def test_shared_prefix_outputs_identical_and_pool_small(self):
        """64 requests sharing a 256-token system prompt fit in a pool far
        smaller than 64 slot-static rows, and stream the same tokens as an
        unshared engine."""
        model, params = _built(_family_cfgs()["attn"])
        shared = [(i * 7 + 3) % 64 for i in range(256)]
        prompts = [shared + [10 + i % 8, 20 + i % 5] for i in range(64)]
        paged = _cfg(paged=True, slots=4, chunk=64, max_len=320,
                     page_size=32, pages=24)
        eng = Engine(model, params, paged)
        # first request to completion: registers the aligned prefix levels
        eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=2))
        eng.run()
        for i in range(1, 64):
            eng.submit(Request(uid=i, prompt=prompts[i], max_new_tokens=2))
        done = eng.run()
        eng._pc.audit()
        assert len(done) == 63
        sla = eng.sla_report()
        assert sla["prefix_hit_tokens"] >= 63 * 256
        # pool is 23 usable pages x 32 tokens = 736 tokens vs 64*320 slots
        assert eng._pc.pool_tokens() < 64 * 320 / 8
        # spot-check outputs against an unshared slot-static engine
        ref = _outputs(model, params,
                       _cfg(paged=False, slots=4, chunk=64, max_len=320),
                       [Request(uid=i, prompt=prompts[i], max_new_tokens=2)
                        for i in (1, 17, 40)])
        by_uid = {r.uid: list(r.output) for r in done}
        for uid, out in ref.items():
            assert by_uid[uid] == out

    def test_state_family_snapshot_sharing(self):
        """Recurrent families share via state snapshots at the hinted
        prefix boundary — outputs identical to the unshared engine."""
        model, params = _built(_family_cfgs()["ssd"])
        shared = [(i * 5 + 1) % 64 for i in range(16)]

        def reqs_of():
            return [Request(uid=i, prompt=shared + [30 + i],
                            max_new_tokens=4, prefix_len=16)
                    for i in range(4)]

        ref = _outputs(model, params, _cfg(paged=False), reqs_of())
        reqs = reqs_of()
        eng = Engine(model, params, _cfg(paged=True))
        eng.submit(reqs[0])
        eng.run()
        for r in reqs[1:]:
            eng.submit(r)
        eng.run()
        eng._pc.audit()
        got = {r.uid: list(r.output) for r in reqs}
        assert got == ref
        assert eng.sla_report()["prefix_hit_tokens"] >= 3 * 16


class TestRefcountInvariants:
    def test_random_admit_cancel_never_leaks(self):
        """Random interleavings of submit / tick / cancel keep the page
        refcounts, free list, and snapshot ownership consistent (audit
        checks the full invariant after every mutation)."""
        model, params = _built(_family_cfgs()["attn"])
        rng = random.Random(7)
        eng = Engine(model, params,
                     _cfg(paged=True, slots=2, max_len=32, page_size=8,
                          pages=9))
        uid = 0
        live: list[int] = []
        for _ in range(120):
            act = rng.random()
            if act < 0.35:
                plen = rng.randrange(1, 20)
                prompt = [rng.randrange(1, 64) for _ in range(plen)]
                eng.submit(Request(uid=uid, prompt=prompt,
                                   max_new_tokens=rng.randrange(1, 6)))
                live.append(uid)
                uid += 1
            elif act < 0.5 and live:
                eng.cancel(live.pop(rng.randrange(len(live))))
            else:
                eng.tick()
            eng._pc.audit()
        eng.run()
        eng._pc.audit()
        # evicting every prefix entry must return the whole pool
        while eng._pc.evict_one():
            eng._pc.audit()
        assert eng._pc.pages.n_free == eng._pc.pages.n_pages - 1

    def test_preempt_then_cancel_releases_everything(self):
        model, params = _built(_family_cfgs()["attn"])
        eng = Engine(model, params,
                     _cfg(paged=True, slots=2, max_len=32, page_size=8,
                          pages=7, prefix=False))
        eng.submit(Request(uid=0, prompt=list(range(1, 9)),
                           max_new_tokens=20, priority=1))
        eng.submit(Request(uid=1, prompt=list(range(9, 17)),
                           max_new_tokens=20, priority=1))
        for _ in range(6):
            eng.tick()
            eng._pc.audit()
        # urgent arrival under pressure forces a preemption
        eng.submit(Request(uid=2, prompt=[3, 4, 5], max_new_tokens=8,
                           priority=0))
        for _ in range(4):
            eng.tick()
            eng._pc.audit()
        for u in (0, 1, 2):
            eng.cancel(u)
            eng._pc.audit()
        eng.run()
        eng._pc.audit()
        assert eng._pc.pages.n_free == eng._pc.pages.n_pages - 1


class TestPreemption:
    def _run(self, model, params, pages):
        eng = Engine(model, params,
                     _cfg(paged=True, slots=2, max_len=64, page_size=8,
                          pages=pages, prefix=False))
        eng.submit(Request(uid=0, prompt=list(range(1, 9)),
                           max_new_tokens=24, priority=1))
        eng.submit(Request(uid=1, prompt=list(range(9, 17)),
                           max_new_tokens=24, priority=1))
        for _ in range(8):
            eng.tick()
        eng.submit(Request(uid=2, prompt=[3, 4, 5], max_new_tokens=8,
                           priority=0))
        eng.run()
        eng._pc.audit()
        return eng

    def test_preemption_deterministic_and_recompute_exact(self):
        """Preempting the lowest-priority generation and recomputing it on
        resume reproduces the unpressured greedy output exactly, run after
        run."""
        model, params = _built(_family_cfgs()["attn"])
        a = self._run(model, params, pages=8)
        b = self._run(model, params, pages=8)
        out_a = {r.uid: list(r.output) for r in a.finished}
        assert out_a == {r.uid: list(r.output) for r in b.finished}
        assert a.stats["preemptions"] > 0
        assert all(len(out_a[u]) == 24 for u in (0, 1))
        roomy = self._run(model, params, pages=33)
        assert roomy.stats["preemptions"] == 0
        assert out_a == {r.uid: list(r.output) for r in roomy.finished}

    def test_admission_never_preempts_equal_priority(self):
        model, params = _built(_family_cfgs()["attn"])
        eng = Engine(model, params,
                     _cfg(paged=True, slots=1, max_len=32, page_size=8,
                          pages=5, prefix=False))
        eng.submit(Request(uid=0, prompt=list(range(1, 9)),
                           max_new_tokens=16, priority=0))
        for _ in range(4):
            eng.tick()
        eng.submit(Request(uid=1, prompt=[3, 4], max_new_tokens=4,
                           priority=0))
        eng.run()
        assert eng.stats["preemptions"] == 0
        by_uid = {r.uid: r for r in eng.finished}
        assert len(by_uid[0].output) == 16
        assert len(by_uid[1].output) == 4
