"""End-to-end trainer + serving-engine tests (fault tolerance included)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import TokenStream
from repro.models import build_model
from repro.optim import adamw, constant_schedule, cosine_schedule
from repro.serve import (Engine, EngineConfig, MemoryConfig, Request,
                         SchedulerConfig)
from repro.train import Trainer, make_train_step


def tiny_cfg(**over):
    return configs.ARCHS["smollm-135m"].reduced(
        vocab=64, d_model=32, n_layers=2, d_ff=64, n_heads=2, n_kv_heads=1,
        **over)


class _Data:
    def __init__(self, cfg, batch=8, seq=32):
        self.stream = TokenStream(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch)

    def batch(self, step):
        return self.stream.batch(step)


class TestTrainer:
    def test_loss_decreases_on_markov_stream(self):
        cfg = tiny_cfg()
        model = build_model(cfg)
        data = _Data(cfg)
        trainer = Trainer(model, adamw(cosine_schedule(3e-3, 60, 5)), data,
                          log_every=1000)
        out = trainer.run(60)
        hist = out["history"]
        assert hist[-1] < hist[0] - 0.3, (hist[0], hist[-1])

    def test_checkpoint_restart_resumes(self, tmp_path):
        cfg = tiny_cfg()
        model = build_model(cfg)
        data = _Data(cfg)
        opt = adamw(constant_schedule(1e-3))
        t1 = Trainer(model, opt, data, checkpoint_dir=str(tmp_path),
                     checkpoint_every=5, log_every=1000)
        out1 = t1.run(10)
        # a "restarted" trainer picks up at step 10 and matches a straight run
        t2 = Trainer(model, opt, data, checkpoint_dir=str(tmp_path),
                     checkpoint_every=5, log_every=1000)
        out2 = t2.run(15)  # resumes from 10, runs 5 more
        assert len(out2["history"]) == 5
        t3 = Trainer(model, opt, _Data(cfg), log_every=1000)
        out3 = t3.run(15)
        assert out2["history"][-1] == pytest.approx(out3["history"][-1],
                                                    rel=1e-3)

    def test_nan_guard_skips_update(self):
        cfg = tiny_cfg()
        model = build_model(cfg)
        opt = adamw(constant_schedule(1e-3))
        step = jax.jit(make_train_step(model, opt))
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        bad = {"tokens": jnp.zeros((2, 33), jnp.int32)}
        # poison the params through a NaN batch? easier: poison one param
        poisoned = jax.tree.map(lambda x: x, params)
        poisoned["embed"] = poisoned["embed"].at[0, 0].set(jnp.nan)
        p2, o2, m = step(poisoned, opt_state, bad)
        assert float(m["skipped"]) == 1.0
        # params unchanged by the skipped update
        np.testing.assert_array_equal(
            np.asarray(p2["final_norm"]["scale"], np.float32),
            np.asarray(poisoned["final_norm"]["scale"], np.float32))

    def test_microbatch_accumulation_matches_full(self):
        cfg = tiny_cfg()
        model = build_model(cfg)
        opt = adamw(constant_schedule(1e-3))
        params = model.init(jax.random.PRNGKey(0))
        batch = _Data(cfg).batch(0)
        s_full = jax.jit(make_train_step(model, opt))
        s_mb = jax.jit(make_train_step(model, opt, microbatch=4))
        p1, _, m1 = s_full(params, opt.init(params), batch)
        p2, _, m2 = s_mb(params, opt.init(params), batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-3, atol=1e-5)


class TestServeEngine:
    def test_continuous_batching_matches_isolated(self):
        """A request served in a busy engine == the same request served in an
        otherwise-idle engine with the SAME slot count (batch rows are
        mathematically independent; identical batch shapes keep the
        compiled reduction order identical too)."""
        cfg = tiny_cfg()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))

        def serve(reqs, slots=2):
            eng = Engine(model, params, EngineConfig(
                scheduler=SchedulerConfig(slots=slots),
                memory=MemoryConfig(max_len=64)))
            for r in reqs:
                eng.submit(r)
            return {r.uid: r.output for r in eng.run()}

        prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10], [11, 12]]
        reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        busy = serve(reqs)   # 5 requests on 2 slots: forces recycling
        for i, p in enumerate(prompts):
            alone = serve([Request(uid=0, prompt=p, max_new_tokens=6)])
            assert busy[i] == alone[0], f"req {i}: {busy[i]} vs {alone[0]}"

    def test_recurrent_family_serving(self):
        cfg = configs.ARCHS["mamba2-130m"].reduced(
            vocab=64, d_model=32, n_layers=2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, EngineConfig(
            scheduler=SchedulerConfig(slots=2),
            memory=MemoryConfig(max_len=32)))
        for i in range(3):
            eng.submit(Request(uid=i, prompt=[1 + i, 2, 3], max_new_tokens=4))
        done = eng.run()
        assert len(done) == 3
        assert all(len(r.output) == 4 for r in done)


def _family_cfgs():
    return {
        "attn": configs.ARCHS["smollm-135m"].reduced(
            vocab=64, d_model=32, n_layers=2, d_ff=64, n_heads=2,
            n_kv_heads=1),
        "mla": configs.ARCHS["deepseek-v3-671b"].reduced(
            vocab=64, d_model=32, n_layers=2),
        "ssd": configs.ARCHS["mamba2-130m"].reduced(
            vocab=64, d_model=32, n_layers=2),
        "rglru": configs.ARCHS["recurrentgemma-2b"].reduced(
            vocab=64, d_model=32, n_layers=4),
    }


class TestChunkedPrefill:
    """The tentpole contract: a prefill chunk is C decode steps, exactly."""

    @pytest.mark.parametrize("family", ["attn", "mla", "ssd", "rglru"])
    def test_greedy_identical_to_token_at_a_time(self, family):
        """Chunked-prefill greedy outputs are token-for-token identical to
        the token-at-a-time path (chunk_size=1) for every mixer family —
        incl. the sliding-window ring buffer (rglru arch's local_attn
        layers) and MoE blocks (deepseek)."""
        cfg = _family_cfgs()[family]
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        step = jax.jit(model.prefill_chunk)

        def serve(prompt, chunk):
            eng = Engine(model, params, EngineConfig(
                scheduler=SchedulerConfig(slots=2, chunk_size=chunk),
                memory=MemoryConfig(max_len=64)), step_fn=step)
            eng.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=5))
            return eng.run()[0].output

        # rglru's local_attn layers have window=16 (reduced): the 30-token
        # prompt drives positions past the ring size, exercising the
        # ring-buffer wrap (survivor writes + pre-write‖chunk attention)
        long = list(range(6, 36)) if family == "rglru" else list(range(6, 15))
        for prompt in ([4, 5], long):
            ref = serve(prompt, 1)
            for chunk in (4, 16):
                assert serve(prompt, chunk) == ref, (family, prompt, chunk)

    def test_step_count_is_ceil_L_over_C_plus_N(self):
        """A request with an L-token prompt and N new tokens costs
        ceil(L/C) + N - 1 jitted steps (the chunk holding the prompt's last
        token samples the first output), not L + N."""
        cfg = _family_cfgs()["attn"]
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        L, N, C = 24, 4, 8
        eng = Engine(model, params, EngineConfig(
            scheduler=SchedulerConfig(slots=1, chunk_size=C),
            memory=MemoryConfig(max_len=64)))
        eng.submit(Request(uid=0, prompt=list(range(1, L + 1)),
                           max_new_tokens=N))
        done = eng.run()
        assert len(done[0].output) == N
        want = -(-L // C) + N - 1
        assert eng.stats["steps"] == want, (eng.stats["steps"], want)
        assert eng.stats["prefill_tokens"] == L
        assert eng.stats["decode_tokens"] == N - 1

    def test_mixed_batch_packs_prefill_and_decode(self):
        """One iteration can carry a prefill chunk in one slot and a decode
        in another; the decode's output stream is unaffected."""
        cfg = _family_cfgs()["attn"]
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        step = jax.jit(model.prefill_chunk)

        def serve_together(stagger):
            eng = Engine(model, params, EngineConfig(
                scheduler=SchedulerConfig(slots=2, chunk_size=8),
                memory=MemoryConfig(max_len=64)), step_fn=step)
            eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=8))
            if stagger:
                # short request decodes while the long prompt prefills
                eng.submit(Request(uid=1, prompt=list(range(4, 24)),
                                   max_new_tokens=4))
            return {r.uid: r.output for r in eng.run()}

        assert serve_together(True)[0] == serve_together(False)[0]

    def test_token_budget_caps_iteration(self):
        """With token_budget < 2·chunk, two concurrently-prefilling slots
        split the budget instead of both taking a full chunk."""
        cfg = _family_cfgs()["attn"]
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, EngineConfig(
            scheduler=SchedulerConfig(slots=2, chunk_size=8, token_budget=8),
            memory=MemoryConfig(max_len=64)))
        for i in range(2):
            eng.submit(Request(uid=i, prompt=list(range(1, 17)),
                               max_new_tokens=2))
        done = eng.run()
        assert len(done) == 2
        assert all(len(r.output) == 2 for r in done)
        # 32 prompt tokens through an 8-token/iteration pipe: ≥ 4 iterations
        assert eng.stats["prefill_tokens"] == 32
        assert eng.stats["steps"] >= 4
