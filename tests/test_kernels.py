"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant as qt
from repro.core import blast
from repro.kernels import ref
from repro.kernels.ops import (blast_matmul, blast_matmul_q, flash_attention,
                               flash_attention_prefill)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


class TestBlastKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "T,m,n,b,r",
        [
            (16, 32, 24, 4, 8),      # tiny
            (64, 64, 64, 2, 16),     # square b=2 (paper Llama b=2 case)
            (40, 48, 32, 8, 12),     # unaligned T / r → padding path
            (128, 96, 96, 3, 33),    # b=3 (paper ViT), odd r
            (8, 256, 128, 16, 24),   # b=16 (paper Llama), small T (decode-ish)
        ],
    )
    def test_matches_oracle(self, T, m, n, b, r, dtype):
        key = jax.random.PRNGKey(hash((T, m, n, b, r)) % 2**31)
        params = blast.init(key, m, n, b, r, dtype=dtype)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, n), dtype=dtype)
        got = blast_matmul(x, params.U, params.S, params.V, interpret=True)
        want = ref.blast_matmul_ref(x, params.U, params.S, params.V)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype))

    def test_batched_leading_dims(self):
        params = blast.init(jax.random.PRNGKey(0), 32, 32, 4, 8)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 32))
        got = blast_matmul(x, params.U, params.S, params.V, interpret=True)
        want = ref.blast_matmul_ref(x, params.U, params.S, params.V)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_block_sizes_explicit(self):
        params = blast.init(jax.random.PRNGKey(0), 64, 64, 4, 32)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
        for bt, br in [(16, 8), (32, 16), (64, 32)]:
            got = blast_matmul(x, params.U, params.S, params.V,
                               block_t=bt, block_r=br, interpret=True)
            want = ref.blast_matmul_ref(x, params.U, params.S, params.V)
            np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def _quantize_blast_factors(params, bits):
    Uq = qt.quantize(params.U, bits=bits, block_axes=(1, 2))
    Sq = qt.quantize(params.S, bits=bits, block_axes=(2,))
    Vq = qt.quantize(params.V, bits=bits, block_axes=(1, 2))
    return Uq, Sq, Vq


def _act_bound(sx, Uq, Sq, Vq):
    """Interval bound on |y_a8 − y_weight_only|: the map x → y is linear in
    x with the (dequantized) quantized factors fixed, and the activation
    codec guarantees |dq(q(x)) − x| ≤ sx/2 per token, so the deviation is
    at most the abs-factor Alg. 1 chain applied to the constant sx/2 row."""
    aU, aS, aV = (np.abs(np.asarray(qt.dequantize(t), np.float64))
                  for t in (Uq, Sq, Vq))
    b, q, _ = aV.shape
    e = np.broadcast_to(np.asarray(sx, np.float64) / 2, (sx.shape[0], b * q))
    z = np.einsum("...jq,jqr->...jr", e.reshape(-1, b, q), aV)
    w = np.einsum("...jr,ijr->...ir", z, aS)
    y = np.einsum("...ir,ipr->...ip", w, aU)
    return y.reshape(sx.shape[0], -1)


class TestBlastKernelIntActivations:
    """W8A8 / W4A8: the fused integer-contraction kernels against the
    integer XLA reference (tight — stage 1 is an exact int32 dot) and
    against the float-activation weight-only path (within the analytic
    activation-rounding bound)."""

    @pytest.mark.parametrize("bits", [8, 4])
    @pytest.mark.parametrize(
        "T,m,n,b,r",
        [
            (16, 32, 24, 4, 8),      # tiny
            (40, 48, 32, 8, 12),     # unaligned T / r → padding path
            (8, 256, 128, 16, 24),   # b=16, decode-ish T
            (1, 128, 128, 16, 16),   # T=1 matvec
        ],
    )
    def test_matches_integer_reference(self, T, m, n, b, r, bits):
        key = jax.random.PRNGKey(hash((T, m, n, b, r, bits)) % 2**31)
        params = blast.init(key, m, n, b, r)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, n))
        Uq, Sq, Vq = _quantize_blast_factors(params, bits)
        got = blast_matmul_q(x, Uq, Sq, Vq, act="int8", interpret=True)
        xq, sx = qt.quantize_act(x)
        want = ref.blast_matmul_a8_ref(
            xq, sx, qt.int_values(Uq), qt.int_values(Sq), qt.int_values(Vq),
            Uq.scale.reshape(b), Sq.scale.reshape(b, b), Vq.scale.reshape(b))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("bits", [8, 4])
    @pytest.mark.parametrize("T,m,n,b,r", [(16, 32, 32, 4, 8),
                                           (8, 64, 48, 4, 16)])
    def test_within_act_bound_of_weight_only(self, T, m, n, b, r, bits):
        params = blast.init(jax.random.PRNGKey(0), m, n, b, r)
        x = jax.random.normal(jax.random.PRNGKey(2), (T, n))
        Uq, Sq, Vq = _quantize_blast_factors(params, bits)
        a8 = np.asarray(blast_matmul_q(x, Uq, Sq, Vq, act="int8",
                                       interpret=True), np.float64)
        w_only = np.asarray(blast_matmul_q(x, Uq, Sq, Vq, interpret=True),
                            np.float64)
        _, sx = qt.quantize_act(x)
        bound = _act_bound(np.asarray(sx), Uq, Sq, Vq)
        assert (np.abs(a8 - w_only) <= bound + 1e-4).all()

    def test_int_kernel_output_dtype_follows_x(self):
        params = blast.init(jax.random.PRNGKey(3), 32, 32, 4, 8)
        Uq, Sq, Vq = _quantize_blast_factors(params, 8)
        for dtype in (jnp.float32, jnp.bfloat16):
            x = jax.random.normal(jax.random.PRNGKey(4), (4, 32), dtype=dtype)
            y = blast_matmul_q(x, Uq, Sq, Vq, act="int8", interpret=True)
            assert y.dtype == dtype and y.shape == (4, 32)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Hq,Hkv,T,S,D,causal,window",
        [
            (1, 4, 4, 64, 64, 32, True, None),     # MHA causal
            (2, 8, 2, 32, 32, 16, True, None),     # GQA
            (1, 4, 1, 48, 48, 32, True, None),     # MQA, unaligned T
            (1, 2, 2, 64, 64, 16, False, None),    # bidirectional (whisper enc)
            (1, 4, 2, 96, 96, 32, True, 32),       # sliding window (griffin)
            (2, 4, 4, 8, 72, 16, True, None),      # decode-ish: short q, long kv
        ],
    )
    def test_matches_oracle(self, B, Hq, Hkv, T, S, D, causal, window, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, Hq, T, D), dtype=dtype)
        k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype=dtype)
        v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype=dtype)
        q_offset = S - T  # decode semantics when S > T
        got = flash_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, block_q=32, block_kv=32,
                              interpret=True)
        want = ref.attention_ref(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype))

    def test_long_window_prefill(self):
        """Local attention over a longer sequence (recurrentgemma pattern)."""
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        B, H, T, D, W = 1, 2, 256, 16, 64
        q = jax.random.normal(ks[0], (B, H, T, D))
        k = jax.random.normal(ks[1], (B, H, T, D))
        v = jax.random.normal(ks[2], (B, H, T, D))
        got = flash_attention(q, k, v, causal=True, window=W,
                              block_q=64, block_kv=64, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True, window=W)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


class TestFlashAttentionPrefill:
    """Prefill-at-offset variant: per-sequence offsets via scalar prefetch
    (the serving engine's C×max_len chunked-prefill step)."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Hq,Hkv,C,S,D,window",
        [
            (3, 4, 2, 16, 96, 16, None),   # GQA, three offsets in one batch
            (2, 2, 2, 8, 64, 32, None),    # MHA, short chunk
            (3, 4, 1, 16, 96, 16, 24),     # MQA + sliding window
            (2, 4, 4, 1, 72, 16, None),    # C=1 degenerates to decode
        ],
    )
    def test_matches_oracle(self, B, Hq, Hkv, C, S, D, window, dtype):
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (B, Hq, C, D), dtype=dtype)
        k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype=dtype)
        v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype=dtype)
        # per-row offsets: fresh slot, mid-stream slot, nearly-full slot
        offs = jnp.asarray([0, (S - C) // 2, S - C][:B], jnp.int32)
        got = flash_attention_prefill(q, k, v, offs, window=window,
                                      block_q=8, block_kv=32, interpret=True)
        want = ref.attention_prefill_ref(q, k, v, offs, window=window)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **tol(dtype))

    def test_matches_fixed_offset_kernel(self):
        """With equal offsets the prefill variant reduces to the classic
        kernel's static q_offset path."""
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        B, H, C, S, D = 2, 4, 16, 64, 16
        q = jax.random.normal(ks[0], (B, H, C, D))
        k = jax.random.normal(ks[1], (B, H, S, D))
        v = jax.random.normal(ks[2], (B, H, S, D))
        off = S - C
        got = flash_attention_prefill(
            q, k, v, jnp.full((B,), off, jnp.int32),
            block_q=8, block_kv=32, interpret=True)
        want = flash_attention(q, k, v, q_offset=off, block_q=8, block_kv=32,
                               interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


class TestDecodeShapes:
    """T=1 matvec (the paper's Table-4 decode regime): the fused kernel's
    single-T-tile path reads every factor exactly once — bandwidth-optimal,
    so the roofline term is the (m+n+b²)·r parameter bytes."""

    def test_blast_matvec_t1(self):
        params = blast.init(jax.random.PRNGKey(0), 128, 128, 16, 24)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 128))
        got = blast_matmul(x, params.U, params.S, params.V, interpret=True)
        want = ref.blast_matmul_ref(x, params.U, params.S, params.V)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_flash_decode_t1(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (2, 4, 1, 16))
        k = jax.random.normal(ks[1], (2, 2, 128, 16))
        v = jax.random.normal(ks[2], (2, 2, 128, 16))
        got = flash_attention(q, k, v, causal=True, q_offset=127,
                              block_q=8, block_kv=32, interpret=True)
        want = ref.attention_ref(q, k, v, causal=True, q_offset=127)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
