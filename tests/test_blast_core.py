"""Unit + property tests for the BLAST core (paper §2, App. A.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import blast
from repro.core.structures import StructureConfig, make_linear

jax.config.update("jax_enable_x64", False)


def rand_params(key, m, n, b, r, dtype=jnp.float32):
    return blast.init(key, m, n, b, r, dtype=dtype)


class TestBlastMatmul:
    @pytest.mark.parametrize("m,n,b,r", [(12, 8, 2, 3), (16, 16, 4, 5), (24, 12, 3, 7), (8, 8, 1, 4)])
    def test_matches_dense(self, m, n, b, r):
        key = jax.random.PRNGKey(0)
        params = rand_params(key, m, n, b, r)
        x = jax.random.normal(jax.random.PRNGKey(1), (5, n))
        y = blast.matmul(x, params)
        A = blast.to_dense(params)
        np.testing.assert_allclose(y, x @ A.T, rtol=2e-5, atol=2e-5)

    def test_batched_leading_dims(self):
        params = rand_params(jax.random.PRNGKey(0), 16, 12, 2, 4)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 12))
        y = blast.matmul(x, params)
        assert y.shape == (2, 3, 16)
        A = blast.to_dense(params)
        np.testing.assert_allclose(y, x @ A.T, rtol=2e-5, atol=2e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 4),
        p=st.integers(1, 6),
        q=st.integers(1, 6),
        r=st.integers(1, 8),
        batch=st.integers(1, 4),
    )
    def test_property_matches_dense(self, b, p, q, r, batch):
        m, n = b * p, b * q
        params = rand_params(jax.random.PRNGKey(b * 131 + r), m, n, b, r)
        x = jax.random.normal(jax.random.PRNGKey(7), (batch, n))
        y = blast.matmul(x, params)
        A = blast.to_dense(params)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ A.T), rtol=5e-4, atol=5e-4)

    def test_grads_flow(self):
        params = rand_params(jax.random.PRNGKey(0), 8, 8, 2, 3)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))

        def loss(p):
            return jnp.sum(blast.matmul(x, p) ** 2)

        g = jax.grad(loss)(params)
        for leaf in jax.tree.leaves(g):
            assert jnp.all(jnp.isfinite(leaf))
            assert float(jnp.abs(leaf).max()) > 0.0


class TestCounts:
    def test_param_count_matches_paper_square(self):
        # paper §2: 2nr + rb² for n×n
        n, b, r = 256, 16, 8
        assert blast.num_params(n, n, b, r) == 2 * n * r + r * b * b

    def test_table9_llama_50pct(self):
        # Llama-7B attn: 4096×4096, b=16, r=1024 → ~50% of dense (Table 9)
        ratio = blast.num_params(4096, 4096, 16, 1024) / (4096 * 4096)
        assert 0.45 < ratio < 0.55
        # MLP: 11008×4096, b=16, r=1488
        ratio = blast.num_params(11008, 4096, 16, 1488) / (11008 * 4096)
        assert 0.45 < ratio < 0.55

    def test_rank_solver_roundtrip(self):
        r = blast.rank_for_compression(4096, 4096, 16, 0.5)
        assert abs(r - 992) <= 2  # 0.5·4096²/(8192+256)
        got = blast.num_params(4096, 4096, 16, r) / (4096 * 4096)
        assert got <= 0.5 + 1e-6


class TestSpecialCases:
    """Paper §2 + App. A.1: low-rank / block-diag / Monarch ⊂ BLAST."""

    def test_low_rank_exact(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        n, m, t, b = 12, 8, 3, 2
        w_down = jax.random.normal(k1, (n, t))
        w_up = jax.random.normal(k2, (t, m))
        params = blast.from_low_rank(w_down, w_up, b)
        A = blast.to_dense(params)
        np.testing.assert_allclose(A, (w_down @ w_up).T, rtol=1e-5, atol=1e-5)

    def test_block_diag_exact(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (3, 4, 5))  # (b, q, p)
        params = blast.from_block_diagonal(w)
        A = blast.to_dense(params)
        expected = jax.scipy.linalg.block_diag(*[w[i].T for i in range(3)])
        np.testing.assert_allclose(A, expected, rtol=1e-5, atol=1e-5)

    def test_monarch_exact(self):
        b, q, k = 3, 4, 5
        L = jax.random.normal(jax.random.PRNGKey(0), (b, q, k))
        R = jax.random.normal(jax.random.PRNGKey(1), (k, b, b))
        params = blast.from_monarch(L, R)
        # reference monarch apply
        x = jax.random.normal(jax.random.PRNGKey(2), (6, b * q))
        u = jnp.einsum("sbq,bqk->sbk", x.reshape(6, b, q), L)
        y_ref = jnp.einsum("sbk,kbc->sck", u, R).reshape(6, b * k)
        y = blast.matmul(x, params)
        np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)

    def test_svd_init_exact_when_full_rank(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (16, 12))  # (n, m)
        params = blast.from_dense_svd(w, b=4, r=12)
        np.testing.assert_allclose(blast.to_dense(params), w.T, rtol=1e-4, atol=1e-4)


class TestStructures:
    @pytest.mark.parametrize("kind", ["dense", "blast", "low_rank", "monarch", "block_diag"])
    def test_apply_shapes_and_finite(self, kind):
        spec = make_linear(24, 16, StructureConfig(kind=kind, b=4, keep_ratio=0.5))
        params = spec.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 24))
        y = spec.apply(params, x)
        assert y.shape == (3, 16)
        assert jnp.all(jnp.isfinite(y))
        # declared shapes match actual params
        for name, arr in params.items():
            assert arr.shape == spec.shapes[name]
        # param count metadata is exact
        total = sum(int(np.prod(a.shape)) for a in params.values())
        assert total == spec.num_params

    @pytest.mark.parametrize("kind", ["blast", "low_rank", "monarch", "block_diag"])
    def test_budget_respected(self, kind):
        d = 256
        spec = make_linear(d, d, StructureConfig(kind=kind, b=8, keep_ratio=0.5))
        assert spec.num_params <= 0.55 * d * d, (kind, spec.num_params / (d * d))

    def test_unstructured_override(self):
        spec = make_linear(8, 8, StructureConfig(kind="blast"), structured=False)
        assert spec.kind == "dense"
