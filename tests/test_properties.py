"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import blast
from repro.core.structures import StructureConfig, make_linear
from repro.data import TokenStream
from repro.models import moe as moe_lib


dims = st.sampled_from([8, 12, 16, 24, 32])
blocks = st.sampled_from([1, 2, 4])
ranks = st.integers(min_value=1, max_value=12)


class TestBlastInvariants:
    @given(m=dims, n=dims, b=blocks, r=ranks)
    @settings(max_examples=20, deadline=None)
    def test_matmul_equals_dense(self, m, n, b, r):
        params = blast.init(jax.random.PRNGKey(m * 31 + n), m, n, b, r)
        x = jax.random.normal(jax.random.PRNGKey(7), (3, n))
        y = blast.matmul(x, params)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(x @ blast.to_dense(params).T),
            rtol=1e-3, atol=1e-3)

    @given(m=dims, n=dims, b=blocks, r=ranks)
    @settings(max_examples=20, deadline=None)
    def test_param_count_formula(self, m, n, b, r):
        params = blast.init(jax.random.PRNGKey(0), m, n, b, r)
        actual = sum(int(np.prod(p.shape)) for p in params)
        assert actual == blast.num_params(m, n, b, r)

    @given(keep=st.floats(min_value=0.05, max_value=1.0), b=blocks)
    @settings(max_examples=20, deadline=None)
    def test_rank_solver_within_budget(self, keep, b):
        m = n = 64
        r = blast.rank_for_compression(m, n, b, keep)
        assert r >= 1
        if r > 1:  # r=1 floor may exceed tiny budgets
            assert blast.num_params(m, n, b, r) <= keep * m * n + (m + n + b * b)

    @given(kind=st.sampled_from(["dense", "blast", "low_rank", "monarch",
                                 "block_diag"]),
           d_in=dims, d_out=dims)
    @settings(max_examples=25, deadline=None)
    def test_structures_shape_contract(self, kind, d_in, d_out):
        spec = make_linear(d_in, d_out,
                           StructureConfig(kind=kind, b=2, keep_ratio=0.5))
        params = spec.init(jax.random.PRNGKey(1))
        x = jax.random.normal(jax.random.PRNGKey(2), (5, d_in))
        y = spec.apply(params, x)
        assert y.shape == (5, d_out)
        assert np.isfinite(np.asarray(y)).all()
        actual = sum(int(np.prod(p.shape)) for p in params.values())
        assert actual == spec.num_params


class TestMoEInvariants:
    @given(n=st.integers(min_value=1, max_value=40),
           e=st.sampled_from([2, 4, 8]),
           cap=st.integers(min_value=1, max_value=8))
    @settings(max_examples=25, deadline=None)
    def test_dispatch_indices_bijective(self, n, e, cap):
        """Every kept assignment occupies exactly one distinct slot."""
        key = jax.random.PRNGKey(n * 100 + e)
        eidx = jax.random.randint(key, (n, 2), 0, e)
        slot_token, pos, keep = moe_lib._dispatch_indices(eidx, e, cap)
        st_np = np.asarray(slot_token)
        filled = st_np[st_np >= 0]
        assert len(filled) == len(set(filled.tolist()))  # no double-booking
        assert len(filled) == int(np.asarray(keep).sum())
        # kept assignments all have pos < capacity
        assert (np.asarray(pos)[np.asarray(keep)] < cap).all()


class TestDataInvariants:
    @given(step=st.integers(min_value=0, max_value=1000),
           seed=st.integers(min_value=0, max_value=5))
    @settings(max_examples=10, deadline=None)
    def test_counter_indexed_determinism(self, step, seed):
        ts = TokenStream(vocab=97, seq_len=8, global_batch=4, seed=seed)
        a = np.asarray(ts.batch(step)["tokens"])
        b = np.asarray(ts.batch(step)["tokens"])
        np.testing.assert_array_equal(a, b)
        assert a.min() >= 0 and a.max() < 97
