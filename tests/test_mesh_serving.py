"""Mesh-parallel serving: sharding-rule congruence for quantized/grouped
leaves (AbstractMesh — no devices) plus simulated-8-device subprocess tests
that the SAME engine code produces token-identical greedy decodes on 1- and
8-device meshes for all four decoder families, including int8 caches, the
paged pool, and a speculative round."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import quant as qt
from repro.core import structures
from repro.launch.sharding import (partition_spec, replication_report,
                                   tree_specs)
from repro.parallel import Parallel


def _parallel(shape=(16, 16), serve=False):
    mesh = AbstractMesh(shape, ("data", "model"))
    return Parallel(mesh=mesh, data_axes=("data",), fsdp_axis="data",
                    model_axis="model",
                    fsdp_axes_override=() if serve else None)


class TestQArraySpecs:
    """QArray {q, scale} pytrees get congruent specs: codes take the leaf's
    logical axes, scales follow their codes' row/block axes."""

    def test_per_row_scale_follows_vocab(self):
        # embedding-like: per-row int8 quant, scale (V, 1)
        qa = qt.quantize(jnp.ones((64, 32)), bits=8, block_axes=(1,))
        spec = tree_specs({"embed": qa}, {"embed": ("vocab", "embed")},
                          _parallel())
        assert spec["embed"].q == P("model", "data")
        # scale dim 0 matches the logical vocab dim → shards with the codes;
        # the reduced (size-1) block axis replicates
        assert spec["embed"].scale == P("model")

    def test_int4_packed_divisibility_on_bytes(self):
        # int4 packs two codes per byte: (64, 32) → q (64, 16); the packed
        # byte axis is what divisibility is judged on
        qa = qt.quantize(jnp.ones((64, 32)), bits=4, block_axes=(1,))
        assert qa.q.shape == (64, 16)
        spec = tree_specs({"w": qa}, {"w": ("vocab", "embed")}, _parallel())
        assert spec["w"].q == P("model", "data")

    def test_indivisible_code_dim_replicates_with_report(self):
        qa = qt.quantize(jnp.ones((60, 32)), bits=8, block_axes=(1,))
        fb = []
        spec = tree_specs({"w": qa}, {"w": ("vocab", "embed")}, _parallel(),
                          fallbacks=fb)
        assert spec["w"].q == P(None, "data")   # 60 % 16 != 0
        assert fb and fb[0]["path"].endswith(".q")

    def test_block_scale_axes_replicate(self):
        # blast-factor-like: U (b, p, r) quantized per (p, r) block →
        # scale (b, 1, 1) replicates while codes shard rank on "model"
        qa = qt.quantize(jnp.ones((4, 32, 32)), bits=8, block_axes=(1, 2))
        spec = tree_specs({"U": qa}, {"U": ("blocks", "out_block", "rank")},
                          _parallel())
        assert spec["U"].q == P(None, "data", "model")
        assert spec["U"].scale == P()


class TestBundleSpecs:
    """Prestacked GroupBundle leaves need no axes() entry: their specs
    derive from the bundle plan — trailing rank shards on "model", leading
    (G, …) stack dims replicate."""

    def _bundle(self, bits=None):
        cfg = structures.StructureConfig(kind="blast", b=4, rank=16)
        specs, params = [], []
        for i in range(2):
            spec = structures.make_linear(64, 64, cfg)
            p = spec.init(jax.random.PRNGKey(i))
            if bits:
                p = spec.quantize(p, bits)
            specs.append(spec)
            params.append(p)
        return structures.prestack(specs, params)

    def test_float_bundle_rank_tp(self):
        gb = self._bundle()
        assert gb is not None
        par = _parallel(shape=(1, 8), serve=True)
        spec = tree_specs({"_bundle": gb}, {}, par)
        # (G, b, p, r=16): G/blocks replicated, out_block fsdp (disabled in
        # serve layout), rank 16 % 8 == 0 → TP on "model"
        assert spec["_bundle"].arrays["U"] == P(None, None, None, "model")
        assert spec["_bundle"].arrays["S"] == P(None, None, None, "model")
        assert spec["_bundle"].arrays["V"] == P(None, None, None, "model")

    def test_bundle_specs_congruent(self):
        gb = self._bundle()
        par = _parallel(shape=(2, 4), serve=True)
        spec = tree_specs({"_bundle": gb}, {}, par)
        # same pytree structure (device_put-able): zip leaves 1:1
        a = jax.tree.structure(gb)
        b = jax.tree.structure(
            spec["_bundle"], is_leaf=lambda x: isinstance(x, P))
        assert a == b
        U = gb.arrays["U"]
        assert spec["_bundle"].arrays["U"] == partition_spec(
            (None, "blocks", "out_block", "rank"), U.shape, par)

    def test_int4_bundle_packs_rank_bytes(self):
        gb = self._bundle(bits=4)
        assert gb is not None and dict(gb.plan_items)["storage"] == "int4"
        par = _parallel(shape=(1, 2), serve=True)
        spec = tree_specs({"_bundle": gb}, {}, par)
        rb = gb.arrays["U"].shape[-1]   # packed byte axis (nibble pairs)
        want = "model" if rb % 2 == 0 else None
        assert spec["_bundle"].arrays["U"][-1] == want
        # per-block scale stacks replicate (constant along rank)
        assert spec["_bundle"].arrays["su"] == P()


class TestReplicationReport:
    def test_counts_bytes_and_leaves(self):
        shapes = {"a": jax.ShapeDtypeStruct((49155, 16), jnp.float32),
                  "b": jax.ShapeDtypeStruct((64, 16), jnp.float32)}
        axes = {"a": ("vocab", None), "b": ("vocab", None)}
        rep = replication_report(shapes, axes, _parallel())
        assert rep["replicated_leaves"] == 1          # only 49155 % 16 != 0
        assert rep["replicated_bytes"] == 49155 * 16 * 4
        assert rep["leaves"][0]["path"] == "/a"
        assert 0 < rep["replicated_frac"] < 1

    def test_clean_tree_reports_empty(self):
        shapes = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
        rep = replication_report(shapes, {"w": ("vocab", "embed")},
                                 _parallel())
        assert rep["replicated_leaves"] == 0 and rep["leaves"] == []


def _run_sub(code, timeout=900):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert "SUBPROCESS_OK" in out.stdout, (out.stdout[-2000:]
                                           + out.stderr[-4000:])


_MESH_PRELUDE = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import dataclasses, jax, jax.numpy as jnp
from repro import configs
from repro.core import structures
from repro.launch.mesh import make_parallel, make_serving_mesh
from repro.models import build_model
from repro.parallel import NO_PARALLEL
from repro.serve import (Engine, EngineConfig, MemoryConfig, SamplingParams,
                         SchedulerConfig, SpeculativeConfig)

def serve_outputs(cfg, mesh_shape, *, paged=False, spec_k=0, max_new=6):
    dp, tp = mesh_shape
    par = (NO_PARALLEL if (dp, tp) == (1, 1)
           else make_parallel(make_serving_mesh(dp, tp), serve=True))
    model = build_model(cfg, par)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(
        scheduler=SchedulerConfig(slots=2, chunk_size=8),
        memory=MemoryConfig(max_len=48, paged=paged),
        speculative=SpeculativeConfig(k=spec_k),
        mesh=f'{dp},{tp}'))
    prompts = [[3, 1, 4, 1, 5], [9, 2, 6, 5, 3, 5, 8]]
    done = eng.generate_batch(prompts, SamplingParams(max_new_tokens=max_new))
    with structures.grouping(True):
        structures.reset_dispatch_count()
        model.prefill_chunk(eng.params,
                            eng.cache if eng.cache is not None
                            else model.init_cache(2, 48),
                            jnp.ones((2, 1), jnp.int32),
                            jnp.zeros((2,), jnp.int32),
                            jnp.ones((2,), jnp.int32))
        launches = structures.dispatch_count()
    return {r.uid: list(r.output) for r in done}, launches, eng
"""


@pytest.mark.slow
class TestMeshServing:
    def test_all_families_token_identical(self):
        """Greedy decode must be token-identical 1-device vs 8-device on
        every decoder family, with the per-shard grouped launch count
        unchanged by the mesh shape."""
        code = _MESH_PRELUDE + """
FAMILIES = {'gqa': 'smollm-135m', 'mla': 'deepseek-v3-671b',
            'ssd': 'mamba2-130m', 'rglru': 'recurrentgemma-2b'}
for family, arch in FAMILIES.items():
    cfg = configs.ARCHS[arch].reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    ref, l1, _ = serve_outputs(cfg, (1, 1))
    got, l8, eng = serve_outputs(cfg, (1, 8))
    assert got == ref, (family, ref, got)
    assert l8 == l1 and l8 > 0, (family, l1, l8)
    assert eng.sharding_report is not None
    assert eng.sharding_report['total_bytes'] > 0
print('SUBPROCESS_OK')
"""
        _run_sub(code)

    def test_int8_cache_paged_and_speculative(self):
        """The three serving extras keep mesh-shape token identity: int8
        KV cache, the paged pool (TP-sharded leaves, replicated page axis),
        and a self-speculative draft round."""
        code = _MESH_PRELUDE + """
from repro.quant import QuantConfig
base = configs.ARCHS['smollm-135m'].reduced()

cfg_q = dataclasses.replace(base, quant=QuantConfig(cache='int8'))
ref, _, _ = serve_outputs(cfg_q, (1, 1))
got, _, _ = serve_outputs(cfg_q, (1, 8))
assert got == ref, ('int8 cache', ref, got)

ref, _, _ = serve_outputs(base, (1, 1), paged=True)
got, _, eng = serve_outputs(base, (1, 8), paged=True)
assert got == ref, ('paged', ref, got)
assert eng._pc is not None

ref, _, _ = serve_outputs(base, (1, 1), spec_k=3, max_new=8)
got, _, eng = serve_outputs(base, (1, 8), spec_k=3, max_new=8)
assert got == ref, ('speculative', ref, got)
assert eng.stats['spec_rounds'] > 0
print('SUBPROCESS_OK')
"""
        _run_sub(code)

    def test_shard_map_grouped_kernels_match(self):
        """The shard_map TP wrappers (each device contracts its rank shard,
        one psum) must match the single-launch grouped kernels for float,
        int8 and packed-int4 storage."""
        code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp
import numpy as np
from repro import quant as qt
from repro.kernels import ops
from repro.launch.mesh import make_serving_mesh

mesh = make_serving_mesh(1, 8)
G, T, b, p, q, r = 2, 8, 4, 8, 8, 16
key = jax.random.PRNGKey(0)
ku, ks, kv, kx = jax.random.split(key, 4)
U = jax.random.normal(ku, (G, b, p, r))
S = jax.random.normal(ks, (G, b, b, r))
V = jax.random.normal(kv, (G, b, q, r))
x = jax.random.normal(kx, (T, b * q))

want = ops.blast_matmul_grouped(x, U, S, V, use_pallas=False)
got = ops.blast_matmul_grouped_tp(x, U, S, V, mesh=mesh, use_pallas=False)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=1e-4, atol=1e-4)

for bits in (8, 4):
    Uq = qt.quantize(U, bits=bits, block_axes=(2, 3))
    Sq = qt.quantize(S, bits=bits, block_axes=(3,))
    Vq = qt.quantize(V, bits=bits, block_axes=(2, 3))
    su, ss, sv = (Uq.scale.reshape(G, b), Sq.scale.reshape(G, b, b),
                  Vq.scale.reshape(G, b))
    if bits == 8:
        want = ops.blast_matmul_grouped_q(x, Uq.q, Sq.q, Vq.q, su, ss, sv,
                                          use_pallas=False)
        got = ops.blast_matmul_grouped_q_tp(x, Uq.q, Sq.q, Vq.q, su, ss, sv,
                                            mesh=mesh, use_pallas=False)
    else:
        want = ops.blast_matmul_grouped_q4(x, Uq.q, Sq.q, Vq.q, su, ss, sv,
                                           use_pallas=False)
        got = ops.blast_matmul_grouped_q4_tp(x, Uq.q, Sq.q, Vq.q, su, ss,
                                             sv, mesh=mesh,
                                             use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)

# indivisible rank falls back to the single-launch path (no shard_map)
got = ops.blast_matmul_grouped_tp(x, U[..., :15], S[..., :15], V[..., :15],
                                  mesh=mesh, use_pallas=False)
want = ops.blast_matmul_grouped(x, U[..., :15], S[..., :15], V[..., :15],
                                use_pallas=False)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=1e-4, atol=1e-4)
print('SUBPROCESS_OK')
"""
        _run_sub(code)
