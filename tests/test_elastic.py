"""Elastic-scaling integration test (slow, subprocess): a checkpoint written
by an unsharded (1-device) trainer restores onto an 8-device 2×4 mesh with
production sharding rules, and training continues from the same loss."""

import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_checkpoint_reshards_onto_mesh(tmp_path):
    code = f"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro import configs, checkpoint as ckpt
from repro.launch.mesh import make_parallel
from repro.launch import sharding as sh
from repro.models import build_model
from repro.parallel import NO_PARALLEL

cfg = configs.ARCHS['smollm-135m'].reduced(
    vocab=64, d_model=64, n_layers=2, d_ff=128, n_heads=4, n_kv_heads=2)

# 1. "old cluster": single device, save params
m0 = build_model(cfg, NO_PARALLEL)
params = m0.init(jax.random.PRNGKey(0))
ckpt.save(r'{tmp_path}', 7, params)

# 2. "new cluster": 2x4 mesh, restore with production shardings
mesh = jax.make_mesh((2, 4), ('data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
parallel = make_parallel(mesh, global_batch=4)
m1 = build_model(cfg, parallel)
shapes = jax.eval_shape(m1.init, jax.random.PRNGKey(0))
shardings = sh.tree_shardings(shapes, m1.axes(), parallel)
restored = ckpt.restore(r'{tmp_path}', 7, shapes, shardings=shardings)

# values survive the reshard bit-exactly
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
# and the restored tree is actually sharded on the new mesh
leaf = restored['cycles']['blk_0']['mixer']['qkv']['U']
assert leaf.sharding.mesh.shape == {{'data': 2, 'model': 4}}
# forward runs under the mesh
out = m1.apply(restored, tokens=jnp.ones((4, 8), jnp.int32))
assert np.isfinite(np.asarray(out.logits, np.float32)).all()
print('ELASTIC_OK')
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-3000:]
