"""Quantized-storage subsystem tests: per-block codec properties
(hypothesis), fused apply_q vs dequantize-then-apply for every structure,
the int8 fused BLAST Pallas kernel vs the fp32 oracle under an *analytic*
interval bound, QArray checkpoint round-trips, and per-family quantized
serving smoke (memory halves, logits stay bounded)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro import quant as qt
from repro.checkpoint import store
from repro.core import blast
from repro.core.structures import StructureConfig, make_linear
from repro.kernels import ref
from repro.kernels.ops import blast_matmul_q
from repro.models import build_model
from repro.quant import QArray, QuantConfig
from repro.serve import (Engine, EngineConfig, MemoryConfig, Request,
                         SchedulerConfig)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property checks fall back to a parametrized grid
    HAVE_HYPOTHESIS = False


# ---- property checks (plain functions so hypothesis and the grid fallback
# ---- exercise identical logic)


def check_roundtrip_error_at_most_half_scale(a, b, c, bits, seed):
    """Per-block symmetric quantization: |x − dq(q(x))| ≤ scale/2
    elementwise (round-to-nearest with an exactly-representable max)."""
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(seed), (a, b, c))
    qa = qt.quantize(x, bits=bits, block_axes=(1, 2))
    err = np.abs(np.asarray(qt.dequantize(qa)) - np.asarray(x))
    bound = np.broadcast_to(np.asarray(qa.scale, np.float32) / 2, err.shape)
    assert (err <= bound + 1e-6).all()


def check_requantization_idempotent(a, b, bits, seed):
    """q(dq(q(x))) == q(x) exactly: the max element quantizes to ±qmax, so
    the recovered scale matches and every code reproduces."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (a, b))
    q1 = qt.quantize(x, bits=bits, block_axes=(1,))
    q2 = qt.quantize(qt.dequantize(q1), bits=bits, block_axes=(1,))
    np.testing.assert_array_equal(np.asarray(qt.int_values(q1)),
                                  np.asarray(qt.int_values(q2)))
    np.testing.assert_allclose(np.asarray(q1.scale),
                               np.asarray(q2.scale), rtol=1e-6)


def check_zero_block_safety(a, b, bits):
    """All-zero blocks: positive scale (no 0/0), exact-zero dequant."""
    x = jnp.zeros((a, b))
    x = x.at[0].set(jax.random.normal(jax.random.PRNGKey(0), (b,)))
    qa = qt.quantize(x, bits=bits, block_axes=(1,))
    s = np.asarray(qa.scale)
    assert (s > 0).all()
    dq = np.asarray(qt.dequantize(qa))
    assert np.isfinite(dq).all()
    np.testing.assert_array_equal(dq[1:], 0.0)


def check_int4_pack_roundtrip_exact(d, seed):
    v = jax.random.randint(jax.random.PRNGKey(seed), (3, d), -7, 8,
                           dtype=jnp.int8)
    packed = qt.pack_int4(v)
    assert packed.shape[-1] == (d + 1) // 2
    np.testing.assert_array_equal(
        np.asarray(qt.unpack_int4(packed, d)), np.asarray(v))


def check_cache_row_codec(seed):
    t = jax.random.normal(jax.random.PRNGKey(seed), (2, 5, 3, 8))
    q, s = qt.quantize_rows(t, scale_dtype=jnp.float32)
    err = np.abs(np.asarray(qt.dequantize_rows(q, s, jnp.float32))
                 - np.asarray(t))
    assert (err <= np.asarray(s)[..., None] / 2 + 1e-6).all()


def check_act_roundtrip_error_at_most_half_scale(T, n, seed):
    """Per-token activation codec: |x − dq(q(x))| ≤ sx/2 elementwise (the
    row max is exactly representable at ±127, everything else rounds)."""
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(seed), (T, n))
    q, sx = qt.quantize_act(x)
    assert q.dtype == jnp.int8 and sx.shape == (T, 1)
    assert sx.dtype == jnp.float32
    err = np.abs(np.asarray(qt.dequantize_act(q, sx)) - np.asarray(x))
    assert (err <= np.asarray(sx) / 2 + 1e-6).all()
    # row max hits a code of magnitude exactly 127
    assert (np.abs(np.asarray(q)).max(axis=-1) == 127).all()


def check_act_zero_row_safety(T, n, seed):
    """All-zero token rows: positive scale (no 0/0), exact-zero codes."""
    x = jnp.zeros((T, n))
    x = x.at[0].set(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
    q, sx = qt.quantize_act(x)
    s = np.asarray(sx)
    assert (s > 0).all() and np.isfinite(s).all()
    np.testing.assert_array_equal(np.asarray(q)[1:], 0)
    np.testing.assert_array_equal(np.asarray(qt.dequantize_act(q, sx))[1:],
                                  0.0)


def check_act_batched_leading_dims(seed):
    """The codec is per *last-axis row* whatever the leading shape."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 3, 8))
    q, sx = qt.quantize_act(x)
    assert q.shape == x.shape and sx.shape == (2, 3, 1)
    qf, sf = qt.quantize_act(x.reshape(6, 8))
    np.testing.assert_array_equal(np.asarray(q).reshape(6, 8), np.asarray(qf))
    np.testing.assert_allclose(np.asarray(sx).reshape(6, 1), np.asarray(sf),
                               rtol=1e-7)


if HAVE_HYPOTHESIS:
    dims = st.sampled_from([4, 8, 12, 16])
    bits_st = st.sampled_from([8, 4])

    class TestCodecProperties:
        @given(a=dims, b=dims, c=dims, bits=bits_st,
               seed=st.integers(min_value=0, max_value=50))
        @settings(max_examples=30, deadline=None)
        def test_roundtrip_error_at_most_half_scale(self, a, b, c, bits, seed):
            check_roundtrip_error_at_most_half_scale(a, b, c, bits, seed)

        @given(a=dims, b=dims, bits=bits_st,
               seed=st.integers(min_value=0, max_value=50))
        @settings(max_examples=30, deadline=None)
        def test_requantization_idempotent(self, a, b, bits, seed):
            check_requantization_idempotent(a, b, bits, seed)

        @given(a=dims, b=dims, bits=bits_st)
        @settings(max_examples=20, deadline=None)
        def test_zero_block_safety(self, a, b, bits):
            check_zero_block_safety(a, b, bits)

        @given(d=st.sampled_from([1, 2, 5, 8, 13]),
               seed=st.integers(min_value=0, max_value=20))
        @settings(max_examples=20, deadline=None)
        def test_int4_pack_roundtrip_exact(self, d, seed):
            check_int4_pack_roundtrip_exact(d, seed)

        @given(seed=st.integers(min_value=0, max_value=20))
        @settings(max_examples=10, deadline=None)
        def test_cache_row_codec(self, seed):
            check_cache_row_codec(seed)

        @given(T=dims, n=dims, seed=st.integers(min_value=0, max_value=50))
        @settings(max_examples=30, deadline=None)
        def test_act_roundtrip_error_at_most_half_scale(self, T, n, seed):
            check_act_roundtrip_error_at_most_half_scale(T, n, seed)

        @given(T=dims, n=dims, seed=st.integers(min_value=0, max_value=20))
        @settings(max_examples=20, deadline=None)
        def test_act_zero_row_safety(self, T, n, seed):
            check_act_zero_row_safety(T, n, seed)

        @given(seed=st.integers(min_value=0, max_value=20))
        @settings(max_examples=10, deadline=None)
        def test_act_batched_leading_dims(self, seed):
            check_act_batched_leading_dims(seed)
else:
    class TestCodecProperties:
        @pytest.mark.parametrize("bits", [8, 4])
        @pytest.mark.parametrize("seed", range(5))
        def test_roundtrip_error_at_most_half_scale(self, bits, seed):
            check_roundtrip_error_at_most_half_scale(4 + seed, 8, 12, bits,
                                                     seed)

        @pytest.mark.parametrize("bits", [8, 4])
        @pytest.mark.parametrize("seed", range(5))
        def test_requantization_idempotent(self, bits, seed):
            check_requantization_idempotent(8, 4 + seed, bits, seed)

        @pytest.mark.parametrize("bits", [8, 4])
        def test_zero_block_safety(self, bits):
            check_zero_block_safety(8, 16, bits)

        @pytest.mark.parametrize("d", [1, 2, 5, 8, 13])
        def test_int4_pack_roundtrip_exact(self, d):
            check_int4_pack_roundtrip_exact(d, d)

        @pytest.mark.parametrize("seed", range(3))
        def test_cache_row_codec(self, seed):
            check_cache_row_codec(seed)

        @pytest.mark.parametrize("seed", range(5))
        def test_act_roundtrip_error_at_most_half_scale(self, seed):
            check_act_roundtrip_error_at_most_half_scale(4 + seed, 8, seed)

        @pytest.mark.parametrize("seed", range(3))
        def test_act_zero_row_safety(self, seed):
            check_act_zero_row_safety(4, 8 + seed, seed)

        @pytest.mark.parametrize("seed", range(3))
        def test_act_batched_leading_dims(self, seed):
            check_act_batched_leading_dims(seed)


class TestStructureApplyQ:
    """apply_q must equal dequantize-then-apply (the fusion is exact) for
    every structure kind and both storage widths."""

    @pytest.mark.parametrize("kind", ["dense", "blast", "low_rank", "monarch",
                                      "block_diag", "pixelfly"])
    @pytest.mark.parametrize("bits", [8, 4])
    def test_fused_equals_dequant_apply(self, kind, bits):
        spec = make_linear(32, 48, StructureConfig(kind=kind, b=4,
                                                   keep_ratio=0.6))
        params = spec.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (7, 32))
        qp = spec.quantize(params, bits)
        dq = {k: (qt.dequantize(v, jnp.float32) if qt.is_qarray(v) else v)
              for k, v in qp.items()}
        np.testing.assert_allclose(
            np.asarray(spec.apply_q(qp, x)), np.asarray(spec.apply(dq, x)),
            rtol=2e-5, atol=2e-5)

    def test_quantized_storage_halves(self):
        spec = make_linear(128, 128, StructureConfig(kind="blast", b=4,
                                                     keep_ratio=0.5))
        params = spec.init(jax.random.PRNGKey(0))
        fp = qt.tree_nbytes(jax.tree.map(
            lambda a: a.astype(jnp.bfloat16), params))
        q8 = qt.tree_nbytes(spec.quantize(params, 8))
        q4 = qt.tree_nbytes(spec.quantize(params, 4))
        assert q8 < 0.6 * fp
        assert q4 < 0.35 * fp


def _quantize_blast(params, bits=8):
    Uq = qt.quantize(params.U, bits=bits, block_axes=(1, 2))
    Sq = qt.quantize(params.S, bits=bits, block_axes=(2,))
    Vq = qt.quantize(params.V, bits=bits, block_axes=(1, 2))
    return Uq, Sq, Vq


def _analytic_bound(x, params, Uq, Sq, Vq):
    """Exact interval bound on |y_q − y_fp|: compose |factor| + scale/2
    against |factor| through the abs-value Alg. 1 chain.  Every quantization
    error is elementwise ≤ scale/2, so the difference of the two abs
    compositions bounds all cross terms at once."""
    aU, aS, aV = (np.abs(np.asarray(t, np.float64))
                  for t in (params.U, params.S, params.V))
    dU = np.broadcast_to(np.asarray(Uq.scale, np.float64) / 2, aU.shape)
    dS = np.broadcast_to(np.asarray(Sq.scale, np.float64) / 2, aS.shape)
    dV = np.broadcast_to(np.asarray(Vq.scale, np.float64) / 2, aV.shape)
    ax = np.abs(np.asarray(x, np.float64))

    def compose(U, S, V):
        b, q, _ = V.shape
        xb = ax.reshape(*ax.shape[:-1], b, q)
        z = np.einsum("...jq,jqr->...jr", xb, V)
        w = np.einsum("...jr,ijr->...ir", z, S)
        y = np.einsum("...ir,ipr->...ip", w, U)
        return y.reshape(*ax.shape[:-1], -1)

    return compose(aU + dU, aS + dS, aV + dV) - compose(aU, aS, aV)


class TestBlastKernelInt8:
    """The fused int8 kernel (interpret mode on CPU): bit-tight against the
    dequantized oracle, and within the analytic quant tolerance of fp32."""

    @pytest.mark.parametrize(
        "T,m,n,b,r",
        [
            (16, 32, 24, 4, 8),
            (64, 64, 64, 2, 16),
            (40, 48, 32, 8, 12),      # unaligned T / r → padding path
            (8, 256, 128, 16, 24),    # b=16, decode-ish T
        ],
    )
    def test_matches_dequant_oracle(self, T, m, n, b, r):
        params = blast.init(jax.random.PRNGKey(T + m), m, n, b, r)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, n))
        Uq, Sq, Vq = _quantize_blast(params)
        got = blast_matmul_q(x, Uq, Sq, Vq, interpret=True)
        want = ref.blast_matmul_q_ref(
            x, qt.int_values(Uq), qt.int_values(Sq), qt.int_values(Vq),
            Uq.scale.reshape(b), Sq.scale.reshape(b, b), Vq.scale.reshape(b))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("T,m,n,b,r", [(16, 32, 32, 4, 8),
                                           (32, 64, 48, 4, 16)])
    def test_within_analytic_tolerance_of_fp32(self, T, m, n, b, r):
        params = blast.init(jax.random.PRNGKey(0), m, n, b, r)
        x = jax.random.normal(jax.random.PRNGKey(2), (T, n))
        Uq, Sq, Vq = _quantize_blast(params)
        got = np.asarray(blast_matmul_q(x, Uq, Sq, Vq, interpret=True),
                         np.float64)
        want = np.asarray(ref.blast_matmul_ref(x, params.U, params.S,
                                               params.V), np.float64)
        bound = _analytic_bound(x, params, Uq, Sq, Vq)
        assert (np.abs(got - want) <= bound + 1e-4).all()

    def test_int4_factors_via_unpack_path(self):
        params = blast.init(jax.random.PRNGKey(3), 32, 32, 4, 8)
        x = jax.random.normal(jax.random.PRNGKey(4), (16, 32))
        Uq, Sq, Vq = _quantize_blast(params, bits=4)
        got = blast_matmul_q(x, Uq, Sq, Vq, interpret=True)
        want = ref.blast_matmul_q_ref(
            x, qt.int_values(Uq), qt.int_values(Sq), qt.int_values(Vq),
            Uq.scale.reshape(4), Sq.scale.reshape(4, 4), Vq.scale.reshape(4))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


class TestQuantConfigActivations:
    def test_requires_quantized_weights(self):
        with pytest.raises(ValueError, match="requires quantized weights"):
            QuantConfig(activations="int8")

    def test_invalid_mode_rejected(self):
        with pytest.raises(ValueError):
            QuantConfig(weights="int8", activations="int4")

    @pytest.mark.parametrize("weights", ["int8", "int4"])
    def test_valid_combinations(self, weights):
        cfg = QuantConfig(weights=weights, activations="int8")
        assert cfg.enabled and cfg.act_bits == 8


class TestCheckpointRoundtrip:
    @pytest.mark.parametrize("bits", [8, 4])
    def test_qarray_tree_roundtrip(self, tmp_path, bits):
        spec = make_linear(24, 16, StructureConfig(kind="blast", b=4,
                                                   keep_ratio=0.5))
        params = spec.init(jax.random.PRNGKey(0))
        qp = {"layer": spec.quantize(params, bits),
              "norm": {"scale": jnp.ones((16,))}}
        store.save(str(tmp_path), 3, qp)
        # restore into a zeroed skeleton: only the static (bits, last_dim)
        # metadata survives — the array values must come from disk
        skeleton = jax.tree.map(jnp.zeros_like, qp)
        restored = store.restore(str(tmp_path), 3, skeleton)
        for k in ("U", "S", "V"):
            got, want = restored["layer"][k], qp["layer"][k]
            assert isinstance(got, QArray) and got.bits == bits
            assert got.last_dim == want.last_dim
            np.testing.assert_array_equal(np.asarray(got.q),
                                          np.asarray(want.q))
            np.testing.assert_array_equal(np.asarray(got.scale),
                                          np.asarray(want.scale))


FAMILY_ARCHS = ["smollm-135m", "deepseek-v3-671b", "mamba2-130m",
                "recurrentgemma-2b"]


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
class TestQuantizedServing:
    """All four decoder families: quantized weights + caches shrink resident
    memory and keep final logits bounded-close to the float path."""

    def _models(self, arch):
        cfg = configs.ARCHS[arch].reduced()
        cfg_q = dataclasses.replace(
            cfg, quant=QuantConfig(weights="int8", cache="int8"))
        return cfg, build_model(cfg), build_model(cfg_q)

    def test_logit_deviation_bounded(self, arch):
        cfg, model, model_q = self._models(arch)
        params = model.init(jax.random.PRNGKey(0))
        params_q = model_q.quantize_params(params, model_q.cfg.quant)
        B, P = 2, 10
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                    cfg.vocab)
        steps = jnp.zeros((B,), jnp.int32)
        n_tok = jnp.full((B,), P, jnp.int32)
        base, _ = model.prefill_chunk(params, model.init_cache(B, 16),
                                      tokens, steps, n_tok)
        quant, _ = model_q.prefill_chunk(params_q, model_q.init_cache(B, 16),
                                         tokens, steps, n_tok)
        base = np.asarray(base, np.float32)
        quant = np.asarray(quant, np.float32)
        assert np.isfinite(quant).all()
        # int8 weights + caches: a loose but meaningful bound on random-init
        # smoke models (observed ≤ 0.07 relative; 4× headroom)
        rel = np.abs(quant - base).max() / (np.abs(base).max() + 1e-9)
        assert rel < 0.3, rel

    def test_memory_reduction_and_engine(self, arch):
        cfg, model, model_q = self._models(arch)
        params = model.init(jax.random.PRNGKey(0))
        base_bytes = (qt.tree_nbytes(jax.tree.map(
            lambda a: a.astype(jnp.bfloat16)
            if a.dtype == jnp.float32 and a.ndim > 1 else a, params))
            + qt.tree_nbytes(model.init_cache(2, 32)))
        eng = Engine(model_q, params, EngineConfig(
            scheduler=SchedulerConfig(slots=2, chunk_size=4),
            memory=MemoryConfig(max_len=32)))
        assert qt.tree_is_quantized(eng.params)  # quantize-at-load fired
        q_bytes = qt.tree_nbytes(eng.params) + qt.tree_nbytes(eng.cache)
        assert q_bytes < 0.75 * base_bytes
        eng.submit(Request(uid=0, prompt=[1, 2, 3, 4, 5], max_new_tokens=3))
        eng.submit(Request(uid=1, prompt=[7, 8, 9], max_new_tokens=2))
        done = eng.run()
        assert sorted(len(r.output) for r in done) == [2, 3]
        assert all(r.done for r in done)

    def test_cache_axes_congruent_with_quant(self, arch):
        _, _, model_q = self._models(arch)
        cache = jax.eval_shape(lambda: model_q.init_cache(2, 16))
        axes = model_q.cache_axes()

        def congruent(c, a, path=""):
            if isinstance(c, dict):
                assert set(c) == set(a), (path, set(c), set(a))
                for k in c:
                    congruent(c[k], a[k], f"{path}/{k}")
            else:
                assert len(a) == c.ndim, path
        congruent(cache, axes)


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
class TestIntActivationServing:
    """W8A8/W4A8 end to end on all four decoder families: a teacher-forced
    greedy decode under the integer-activation mode stays bounded-close to
    the weight-only quantized path, which itself stays close to float."""

    def test_greedy_decode_logit_deviation(self, arch):
        from repro.core import structures
        cfg = configs.ARCHS[arch].reduced()
        qcfg = QuantConfig(weights="int4", activations="int8")
        cfg_q = dataclasses.replace(cfg, quant=qcfg)
        model = build_model(cfg)
        model_q = build_model(cfg_q)
        params = model.init(jax.random.PRNGKey(0))
        params_q = model_q.quantize_params(params, qcfg)
        B, P, STEPS = 2, 6, 3
        prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                    cfg.vocab)

        def decode(model_, params_, act):
            """Prefill then STEPS greedy decode steps, teacher-forced on the
            float model's tokens so logits stay comparable step by step."""
            cache = model_.init_cache(B, 16)
            steps = jnp.zeros((B,), jnp.int32)
            n_tok = jnp.full((B,), P, jnp.int32)
            with structures.activations(act):
                logits, cache = model_.prefill_chunk(params_, cache, prompt,
                                                     steps, n_tok)
            traj = [logits]
            pos = P
            for _ in range(STEPS):
                tok = jnp.argmax(traj[-1][:, -1], axis=-1)[:, None]
                tok = tok.astype(jnp.int32) % cfg.vocab
                with structures.activations(act):
                    logits, cache = model_.prefill_chunk(
                        params_, cache, tok,
                        jnp.full((B,), pos, jnp.int32),
                        jnp.ones((B,), jnp.int32))
                traj.append(logits)
                pos += 1
            return [np.asarray(l, np.float32) for l in traj]

        base = decode(model, params, "none")
        w4 = decode(model_q, params_q, "none")
        w4a8 = decode(model_q, params_q, "int8")
        for lb, l4, l48 in zip(base, w4, w4a8):
            assert np.isfinite(l48).all()
            scale = np.abs(lb).max() + 1e-9
            # activation rounding adds little on top of the int4 weight error
            rel_w = np.abs(l4 - lb).max() / scale
            rel_a = np.abs(l48 - lb).max() / scale
            assert rel_a < max(3.0 * rel_w, 0.15), (rel_a, rel_w)

    def test_engine_quantizes_and_serves_w4a8(self, arch):
        from repro.core import structures
        cfg = configs.ARCHS[arch].reduced()
        qcfg = QuantConfig(weights="int4", cache="int8", activations="int8")
        cfg_q = dataclasses.replace(cfg, quant=qcfg)
        model_q = build_model(cfg_q)
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        try:
            eng = Engine(model_q, params, EngineConfig(
                scheduler=SchedulerConfig(slots=2, chunk_size=4),
                memory=MemoryConfig(max_len=32)))
            # engine build flips the process-wide activation mode
            assert structures.activations_mode() == "int8"
            assert qt.tree_is_quantized(eng.params)
            eng.submit(Request(uid=0, prompt=[1, 2, 3, 4], max_new_tokens=3))
            done = eng.run()
            assert len(done) == 1 and len(done[0].output) == 3
        finally:
            structures.set_activations("none")
