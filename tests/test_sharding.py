"""Sharding-rule unit tests (AbstractMesh — no devices needed) plus a
subprocess dry-run smoke on a small forced-device-count mesh."""

import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.launch.sharding import partition_spec
from repro.parallel import Parallel


def _parallel(multi_pod=False):
    if multi_pod:
        mesh = AbstractMesh((2, 16, 16), ("pod", "data", "model"))
        return Parallel(mesh=mesh, data_axes=("pod", "data"),
                        fsdp_axis="data", model_axis="model")
    mesh = AbstractMesh((16, 16), ("data", "model"))
    return Parallel(mesh=mesh, data_axes=("data",), fsdp_axis="data",
                    model_axis="model")


class TestPartitionSpec:
    def test_blast_factor_tp_on_rank(self):
        p = _parallel()
        # U: (b, p, r) — out_block fsdp, rank TP
        spec = partition_spec(("blocks", "out_block", "rank"),
                              (16, 256, 1024), p)
        assert spec == P(None, "data", "model")

    def test_used_axis_not_reused(self):
        p = _parallel()
        # experts take "model"; per-expert rank must fall back to replicated
        spec = partition_spec(("experts", "out_block", "rank"),
                              (32, 256, 1024), p)
        assert spec == P("model", "data")

    def test_indivisible_dim_replicates(self):
        p = _parallel()
        spec = partition_spec(("vocab", "embed"), (49155, 2048), p)
        assert spec == P(None, "data")  # 49155 % 16 != 0

    def test_multipod_fsdp_tuple(self):
        p = _parallel(multi_pod=True)
        spec = partition_spec(("fsdp_in", "model_out"), (4096, 4096), p)
        assert spec == P(("pod", "data"), "model")

    def test_multipod_fsdp_falls_back_to_suffix(self):
        p = _parallel(multi_pod=True)
        # 48 % 32 != 0 but 48 % 16 == 0 → shard over ("data",) only
        spec = partition_spec(("fsdp_in", "model_out"), (48, 4096), p)
        assert spec == P("data", "model")

    def test_trailing_nones_trimmed(self):
        p = _parallel()
        spec = partition_spec((None, None), (3, 5), p)
        assert spec == P()


@pytest.mark.slow
class TestDryRunSubprocess:
    def test_small_mesh_cell_compiles(self, tmp_path):
        """End-to-end: lower+compile a train cell on a forced 8-device host."""
        code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import dataclasses, jax
from repro.configs import SHAPES, get
from repro.launch.cells import make_cell, lower_cell
from repro.launch.mesh import make_parallel
from repro.roofline import analyze_compiled
mesh = jax.make_mesh((2, 4), ('data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = get('smollm-135m')
shape = dataclasses.replace(SHAPES['train_4k'], global_batch=4, seq_len=128)
cell = make_cell(cfg, shape, make_parallel(mesh, global_batch=4))
compiled = lower_cell(cell).compile()
t = analyze_compiled(compiled)
assert t.flops > 0 and t.coll_bytes > 0, (t.flops, t.coll_bytes)
print('SUBPROCESS_OK')
"""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
        assert "SUBPROCESS_OK" in out.stdout, out.stderr[-2000:]
