"""Serving resilience: fault injection (serve/faults.py), numeric
guardrails + the degradation ladder, driver-fault isolation (batch bisect),
watchdog, deadlines, shedding, and the HTTP-layer failure surface.

The headline invariant every chaos test here pins: a fault stays contained
to the request it targets — every non-faulted request completes with greedy
output token-identical to a fault-free run of the same engine
configuration."""

import asyncio
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import structures
from repro.models import build_model
from repro.serve import (Engine, EngineConfig, MemoryConfig, Request,
                         ResilienceConfig, SamplingParams, SchedulerConfig,
                         SpeculativeConfig)
from repro.serve import resilience as rsl
from repro.serve.faults import Fault, FaultPlan
from repro.serve.http import Server


def _family_cfgs():
    return {
        "attn": configs.ARCHS["smollm-135m"].reduced(
            vocab=64, d_model=32, n_layers=2, d_ff=64, n_heads=2,
            n_kv_heads=1),
        "mla": configs.ARCHS["deepseek-v3-671b"].reduced(
            vocab=64, d_model=32, n_layers=2),
        "ssd": configs.ARCHS["mamba2-130m"].reduced(
            vocab=64, d_model=32, n_layers=2),
        "rglru": configs.ARCHS["recurrentgemma-2b"].reduced(
            vocab=64, d_model=32, n_layers=4),
    }


def _built(cfg):
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _tiny():
    return _built(_family_cfgs()["attn"])


def _config(res=None, sched=None, **mem):
    return EngineConfig(
        scheduler=sched or SchedulerConfig(slots=2, chunk_size=8),
        memory=MemoryConfig(max_len=64, **mem),
        resilience=res or ResilienceConfig())


def _reqs(n=3, max_new=8):
    prompts = [[4, 5], list(range(6, 15)), [7, 8, 9], [9, 3, 5, 7],
               [11, 12], [13, 14, 15]]
    return [Request(uid=i + 1, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts[:n])]


def _serve(model, params, cfg, reqs):
    eng = Engine(model, params, cfg)
    for r in reqs:
        eng.submit(r)
    eng.run()
    eng.close()
    return eng


def _baseline(model, params, cfg, n=3, max_new=8):
    """Fault-free greedy outputs {uid: tokens} for the same request mix."""
    clean = dataclasses.replace(cfg, resilience=ResilienceConfig())
    reqs = _reqs(n, max_new)
    _serve(model, params, clean, reqs)
    return {r.uid: list(r.output) for r in reqs}


class TestFaultPlan:
    def test_spec_grammar_all_kinds(self):
        plan = FaultPlan.from_spec(
            "nan@6:u3:x2; raise@12:u1:known, slow@20:0.5;drop@2:u4")
        kinds = [f.kind for f in plan.faults]
        assert kinds == ["nan_logits", "driver_error", "slow_step",
                         "drop_conn"]
        nan, rse, slw, drp = plan.faults
        assert (nan.step, nan.uid, nan.count) == (6, 3, 2)
        assert (rse.step, rse.uid, rse.known) == (12, 1, True)
        assert (slw.step, slw.delay_s) == (20, 0.5)
        assert (drp.uid, drp.events) == (4, 2)
        assert plan.faulted_uids() == {3, 1, 4}

    @pytest.mark.parametrize("bad", ["nan@6", "raise@3", "slow@1:u2",
                                     "warp@4:u1", "nan@2:z9"])
    def test_spec_grammar_rejects(self, bad):
        with pytest.raises(ValueError):
            FaultPlan.from_spec(bad)

    def test_seeded_deterministic(self):
        a = FaultPlan.seeded(7, [1, 2, 3])
        b = FaultPlan.seeded(7, [1, 2, 3])
        assert [f.describe() for f in a.faults] == \
               [f.describe() for f in b.faults]
        c = FaultPlan.seeded(8, [1, 2, 3])
        assert [f.describe() for f in a.faults] != \
               [f.describe() for f in c.faults]

    def test_poll_firing_rules(self):
        plan = FaultPlan([Fault("nan_logits", 3, uid=1, count=2),
                          Fault("slow_step", 4, delay_s=0.1),
                          Fault("driver_error", 5, uid=2)])
        assert plan.poll("nan_logits", 2, [1]) == []        # before step
        assert plan.poll("nan_logits", 3, [2]) == []        # uid absent
        assert len(plan.poll("nan_logits", 3, [1, 2])) == 1
        assert len(plan.poll("nan_logits", 4, [1])) == 1    # count=2
        assert plan.poll("nan_logits", 5, [1]) == []        # exhausted
        assert len(plan.poll("slow_step", 9, [1])) == 1
        assert plan.poll("slow_step", 10, [1]) == []        # fires once
        # driver_error persists while its uid keeps being scheduled
        assert len(plan.poll("driver_error", 5, [2])) == 1
        assert len(plan.poll("driver_error", 6, [2])) == 1
        rep = plan.report()
        assert rep["fired"] == 5 and rep["fired_by_kind"] == {
            "nan_logits": 2, "slow_step": 1, "driver_error": 2}


class TestPrimitives:
    def test_row_health_flags_bad_rows_only(self):
        lg = jnp.ones((4, 3, 5))
        lg = lg.at[1, 0, 0].set(jnp.nan)
        lg = lg.at[2, 2, 4].set(jnp.inf)
        assert structures.row_health(lg).tolist() == [True, False, False,
                                                      True]
        lg2 = jnp.ones((3, 5)).at[0, 1].set(2e6)
        assert structures.row_health(lg2, absmax=1e6).tolist() == \
            [False, True, True]
        assert structures.row_health(lg2).tolist() == [True, True, True]

    def test_backoff_deterministic_and_bounded(self):
        a = rsl.Backoff(0.5, 30.0, seed=3)
        b = rsl.Backoff(0.5, 30.0, seed=3)
        da = [a.delay(i) for i in range(8)]
        assert da == [b.delay(i) for i in range(8)]
        for i, d in enumerate(da):
            raw = min(30.0, 0.5 * 2 ** i)
            assert 0.5 * raw <= d < raw
        assert a.delay(40) < 30.0   # capped

    def test_bisect_groups(self):
        assert rsl.bisect_groups([1, 2, 3, 4]) == [[1, 2], [3, 4]]
        assert rsl.bisect_groups([1, 2, 3]) == [[1], [2, 3]]
        assert rsl.bisect_groups([5]) == [[5]]


class TestNumericDegradation:
    def test_nan_trip_recovers_token_identical(self):
        model, params = _tiny()
        base = _baseline(model, params, _config())
        res = ResilienceConfig(fault_spec="nan@4:u2")
        reqs = _reqs()
        eng = _serve(model, params, _config(res=res), reqs)
        assert {r.uid: list(r.output) for r in reqs} == base
        hit = next(r for r in reqs if r.uid == 2)
        assert hit.stop_reason == "length"
        assert hit.degrade_path == ["spec_off"]
        assert eng.stats["numeric_trips"] == 1
        assert eng.stats["requeues"] >= 1
        assert eng.health.snapshot()["numeric_trips"] == 1

    def test_ladder_exhaustion_fails_only_target(self):
        model, params = _tiny()
        base = _baseline(model, params, _config())
        res = ResilienceConfig(fault_spec="nan@4:u2:x3")
        reqs = _reqs()
        eng = _serve(model, params, _config(res=res), reqs)
        hit = next(r for r in reqs if r.uid == 2)
        # rung order is the ladder order: speculation off first, then the
        # activation-quant fallback, then the request alone is failed
        assert hit.degrade_path == ["spec_off", "act_float"]
        assert hit.stop_reason == "numeric_error"
        assert eng.stats["numeric_error_failures"] == 1
        assert eng.stats["degrade_spec_off"] == 1
        assert eng.stats["degrade_act_float"] == 1
        for r in reqs:
            if r.uid != 2:
                assert list(r.output) == base[r.uid]
                assert r.stop_reason == "length"

    @pytest.mark.parametrize("family", ["attn", "mla", "ssd", "rglru"])
    def test_chaos_all_families_paged_spec_int8(self, family):
        """The hard configuration: int8 KV/state cache + paged pool +
        self-speculative decoding, with a NaN fault and a driver fault in
        the same run — non-faulted requests stay token-identical."""
        from repro.quant import QuantConfig
        cfg = dataclasses.replace(_family_cfgs()[family],
                                  quant=QuantConfig(weights="int8",
                                                    cache="int8"))
        model, params = _built(cfg)
        mk = lambda res: EngineConfig(
            scheduler=SchedulerConfig(slots=2, chunk_size=8),
            memory=MemoryConfig(max_len=64, paged=True, page_size=8),
            speculative=SpeculativeConfig(k=3),
            resilience=res)
        base = _baseline(model, params, mk(ResilienceConfig()))
        res = ResilienceConfig(fault_spec="nan@4:u2;raise@8:u3")
        reqs = _reqs()
        eng = _serve(model, params, mk(res), reqs)
        assert eng.fault_plan.report()["fired_by_kind"]["nan_logits"] == 1
        assert eng.stats["step_errors"] >= 1
        for r in reqs:
            if r.uid == 2:
                assert r.stop_reason == "length"   # recovered via ladder
                assert r.output == base[r.uid]     # greedy: still identical
            elif r.uid == 3:
                assert r.stop_reason == "error"
            else:
                assert list(r.output) == base[r.uid]
                assert r.stop_reason == "length"


class TestDriverIsolation:
    def test_unknown_uid_bisected_others_identical(self):
        model, params = _tiny()
        base = _baseline(model, params, _config())
        res = ResilienceConfig(fault_spec="raise@6:u2")
        reqs = _reqs()
        eng = _serve(model, params, _config(res=res), reqs)
        hit = next(r for r in reqs if r.uid == 2)
        assert hit.stop_reason == "error"
        assert eng.stats["step_errors"] >= 2   # fault persisted into probes
        for r in reqs:
            if r.uid != 2:
                assert list(r.output) == base[r.uid]
                assert r.stop_reason == "length"

    def test_known_uid_skips_bisect(self):
        model, params = _tiny()
        base = _baseline(model, params, _config())
        res = ResilienceConfig(fault_spec="raise@6:u2:known")
        reqs = _reqs()
        eng = _serve(model, params, _config(res=res), reqs)
        hit = next(r for r in reqs if r.uid == 2)
        assert hit.stop_reason == "error"
        # the exception named its uid: exactly one failing step, no probe
        assert eng.stats["step_errors"] == 1
        for r in reqs:
            if r.uid != 2:
                assert list(r.output) == base[r.uid]


class TestWatchdogDeadlinesShedding:
    def test_watchdog_trips_without_wedging(self):
        model, params = _tiny()
        res = ResilienceConfig(fault_spec="slow@3:0.4",
                               watchdog_deadline_s=0.15)
        reqs = _reqs()
        eng = _serve(model, params, _config(res=res), reqs)
        snap = eng.health.snapshot()
        assert snap["watchdog_trips"] >= 1
        assert all(r.stop_reason == "length" for r in reqs)
        assert eng._watchdog is None   # close() stopped the thread

    def test_request_deadline_expires(self):
        model, params = _tiny()
        reqs = _reqs()
        reqs[1].deadline_s = 0.0   # already expired at first tick
        eng = _serve(model, params, _config(), reqs)
        assert reqs[1].stop_reason == "deadline"
        assert reqs[1].t_done is not None
        assert all(r.stop_reason == "length" for r in reqs
                   if r.uid != reqs[1].uid)
        assert eng.stats["deadline_expired"] == 1

    def test_shed_above_high_water(self):
        model, params = _tiny()
        res = ResilienceConfig(queue_high_water=3)
        reqs = _reqs(6)
        eng = _serve(model, params, _config(res=res), reqs)
        shed = [r for r in reqs if r.stop_reason == "shed"]
        kept = [r for r in reqs if r.stop_reason == "length"]
        assert len(shed) == 3 and len(kept) == 3
        assert eng.stats["shed"] == 3
        # newest-first shedding: the first-submitted requests survive
        assert {r.uid for r in kept} == {1, 2, 3}
        assert eng.overloaded() is False

    def test_sla_report_nulls_for_empty_class(self):
        model, params = _tiny()
        res = ResilienceConfig(queue_high_water=0)
        reqs = _reqs(2)
        eng = _serve(model, params, _config(res=res), reqs)
        assert all(r.stop_reason == "shed" for r in reqs)
        c0 = eng.sla_report()["classes"]["0"]
        assert c0["requests"] == 2 and c0["completed"] == 0
        assert c0["stop_reasons"] == {"shed": 2}
        # explicit nulls, never a fabricated 0.0 latency
        assert c0["ttft_p50_s"] is None and c0["tpot_p99_s"] is None

    def test_healthz_and_resilience_report(self):
        model, params = _tiny()
        res = ResilienceConfig(fault_spec="nan@4:u2")
        reqs = _reqs()
        eng = _serve(model, params, _config(res=res, paged=True,
                                            page_size=8), reqs)
        hz = eng.healthz()
        assert hz["state"] in ("ok", "degraded")
        assert hz["queue_depth"] == 0 and hz["active"] == 0
        assert hz["slots"] == 2 and hz["overloaded"] is False
        assert "occupancy" in hz
        rep = eng.resilience_report()
        assert rep["numeric_trips"] == 1
        assert rep["faults"]["fired"] == 1


async def _raw(port, method, path, body=b""):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    req = f"{method} {path} HTTP/1.1\r\nHost: t\r\n".encode()
    req += b"Content-Length: %d\r\n\r\n%s" % (len(body), body)
    writer.write(req)
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, payload = raw.partition(b"\r\n\r\n")
    lines = head.decode().split("\r\n")
    status = int(lines[0].split(" ")[1])
    headers = {}
    for ln in lines[1:]:
        k, _, v = ln.partition(":")
        headers[k.strip().lower()] = v.strip()
    return status, headers, payload


class TestHTTPResilience:
    def test_structured_errors_and_healthz(self):
        model, params = _tiny()
        eng = Engine(model, params, _config())

        async def run():
            srv = Server(eng, port=0)
            port = await srv.start()
            bad = await _raw(port, "POST", "/v1/generate",
                             b'{"prompt": "oops"}')
            missing = await _raw(port, "POST", "/v1/generate", b"{}")
            nf = await _raw(port, "GET", "/nope")
            hz = await _raw(port, "GET", "/healthz")
            await srv.stop()
            return bad, missing, nf, hz

        bad, missing, nf, hz = asyncio.run(run())
        assert bad[0] == 400
        assert json.loads(bad[2])["error"]["reason"].startswith("prompt:")
        assert missing[0] == 400
        assert "missing" in json.loads(missing[2])["error"]["reason"]
        assert nf[0] == 404
        assert json.loads(nf[2])["error"]["type"] == "not_found"
        assert hz[0] == 200
        assert json.loads(hz[2])["state"] == "ok"
        eng.close()

    def test_overloaded_429_with_retry_after(self):
        model, params = _tiny()
        eng = Engine(model, params, _config(
            res=ResilienceConfig(queue_high_water=0)))

        async def run():
            srv = Server(eng, port=0)
            port = await srv.start()
            r1 = await _raw(port, "POST", "/v1/generate",
                            b'{"prompt": [4, 5]}')
            r2 = await _raw(port, "POST", "/v1/generate",
                            b'{"prompt": [4, 5]}')
            await srv.stop()
            return r1, r2

        r1, r2 = asyncio.run(run())
        assert r1[0] == 429 and r2[0] == 429
        assert json.loads(r1[2])["error"]["type"] == "overloaded"
        assert int(r1[1]["retry-after"]) >= 1
        # the shared backoff advances across consecutive rejections
        assert int(r2[1]["retry-after"]) >= int(r1[1]["retry-after"])
        eng.close()

    def test_draining_503(self):
        model, params = _tiny()
        eng = Engine(model, params, _config())
        eng.health.drain()

        async def run():
            srv = Server(eng, port=0)
            port = await srv.start()
            gen = await _raw(port, "POST", "/v1/generate",
                             b'{"prompt": [4, 5]}')
            hz = await _raw(port, "GET", "/healthz")
            await srv.stop()
            return gen, hz

        gen, hz = asyncio.run(run())
        assert gen[0] == 503 and "retry-after" in gen[1]
        assert json.loads(gen[2])["error"]["type"] == "draining"
        assert hz[0] == 503 and "retry-after" in hz[1]
        eng.close()

    def test_sse_heartbeat_between_tokens(self):
        model, params = _tiny()
        # a 0.6 s stall with a 0.05 s heartbeat: the stream must carry SSE
        # comment lines while the engine is stuck, and still deliver every
        # token afterwards
        eng = Engine(model, params, _config(
            res=ResilienceConfig(fault_spec="slow@2:0.6",
                                 heartbeat_s=0.05)))
        ref = Engine(model, params, _config()).generate_batch(
            [[4, 5, 6]], SamplingParams(max_new_tokens=5))[0].output

        async def run():
            srv = Server(eng, port=0)
            port = await srv.start()
            reader, writer = await asyncio.open_connection("127.0.0.1",
                                                           port)
            body = b'{"prompt": [4, 5, 6], "max_new_tokens": 5}'
            writer.write(b"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                         b"Content-Length: %d\r\n\r\n%s"
                         % (len(body), body))
            await writer.drain()
            events, heartbeats = [], 0
            while True:
                line = await asyncio.wait_for(reader.readline(), timeout=60)
                if not line:
                    break
                if line.startswith(b": hb"):
                    heartbeats += 1
                elif line.startswith(b"data: "):
                    events.append(json.loads(line[6:]))
                    if events[-1].get("done"):
                        break
            writer.close()
            await srv.stop()
            return events, heartbeats

        events, heartbeats = asyncio.run(run())
        assert heartbeats >= 1
        assert [e["token"] for e in events[:-1]] == ref
        assert events[-1]["done"] and events[-1]["stop_reason"] == "length"
        eng.close()
