"""Model-layer equivalence oracles:

  * chunked attention == full-softmax reference (causal / window / GQA)
  * cached decode == full forward, token-for-token, for every mixer family
    (attention, MLA with absorbed latent decode, RG-LRU, SSD)
  * MoE capacity dispatch == dense every-expert oracle when nothing drops
  * SSD chunked scan == naive per-token recurrence
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model, ops
from repro.models import moe as moe_lib


def fwd_vs_decode(arch, B=2, T=12, tol=2e-2):
    """Teacher-forced decode must reproduce apply() logits step-by-step."""
    cfg = configs.ARCHS[arch].reduced(param_dtype="float32",
                                      compute_dtype="float32")
    if cfg.moe is not None:
        # capacity drops differ between a 1-token decode and a T-token
        # forward (both are correct capacity-MoE behavior); equivalence
        # holds exactly when capacity is ample.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    if cfg.encoder is not None:
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.encoder.n_frames, cfg.d_model))
        full = model.apply(params, tokens, frames).logits
        cache = model.init_cache(params, frames, T)
    else:
        full = model.apply(params, tokens=tokens).logits
        cache = model.init_cache(B, T)
    step_fn = jax.jit(model.decode_step)
    for t in range(T):
        logits, cache = step_fn(params, cache, tokens[:, t: t + 1],
                                jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full[:, t], np.float32), rtol=tol, atol=tol,
            err_msg=f"{arch} step {t}")


class TestDecodeEquivalence:
    @pytest.mark.parametrize("arch", [
        "smollm-135m",            # GQA attention
        "granite-3-2b",           # GQA, tied embeddings
        "deepseek-v3-671b",       # MLA absorbed-latent decode + MoE
        "recurrentgemma-2b",      # RG-LRU + local attention ring buffer
        "mamba2-130m",            # SSD recurrent decode
        "whisper-base",           # enc-dec with cross-attention cache
    ])
    def test_decode_matches_forward(self, arch):
        fwd_vs_decode(arch)

    def test_local_attn_ring_buffer(self):
        """Sliding-window ring cache == full forward when T > window."""
        cfg = configs.ARCHS["recurrentgemma-2b"].reduced(
            param_dtype="float32", compute_dtype="float32", window=8)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, T = 1, 20  # T > window=8: ring buffer must wrap
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
        full = model.apply(params, tokens=tokens).logits
        cache = model.init_cache(B, T)
        step_fn = jax.jit(model.decode_step)
        for t in range(T):
            logits, cache = step_fn(params, cache, tokens[:, t: t + 1],
                                    jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(full[:, -1], np.float32),
                                   rtol=2e-2, atol=2e-2)


class TestChunkedAttention:
    @pytest.mark.parametrize("causal,window", [(True, None), (True, 8),
                                               (False, None)])
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (6, 1)])
    def test_matches_reference(self, causal, window, hq, hkv):
        from repro.kernels import ref
        B, T, D = 2, 24, 16
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(kq, (B, hq, T, D))
        k = jax.random.normal(kk, (B, hkv, T, D))
        v = jax.random.normal(kv, (B, hkv, T, D))
        got = ops.chunked_attention(q, k, v, causal=causal, window=window,
                                    q_chunk=2)
        want = ref.attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestMoEDispatch:
    def _spec(self, E=4, k=2, d=16, cf=64.0):
        import dataclasses
        cfg = configs.ARCHS["granite-moe-1b-a400m"].reduced(
            param_dtype="float32", compute_dtype="float32")
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, n_experts=E, top_k=k,
                                         capacity_factor=cf))
        spec = moe_lib.make_moe(cfg)
        params = moe_lib.moe_init(spec, jax.random.PRNGKey(0), jnp.float32)
        return spec, params

    def test_matches_dense_oracle_when_no_drops(self):
        spec, params = self._spec()
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64))
        y, aux = moe_lib.moe_apply(spec, params, x)
        # oracle: run EVERY expert on every token, combine top-k
        x2 = x.reshape(-1, 64)
        gates, eidx, _ = moe_lib._route(spec, params["router"], x2)
        ye_all = jnp.stack([
            moe_lib._expert_ffn(
                spec, jax.tree.map(lambda a: a[e: e + 1], params), x2[None]
            )[0] for e in range(spec.moe.n_experts)])
        want = jnp.einsum("tk,tkd->td", gates,
                          ye_all[eidx, jnp.arange(x2.shape[0])[:, None]])
        np.testing.assert_allclose(np.asarray(y.reshape(-1, 64)),
                                   np.asarray(want), rtol=1e-4, atol=1e-4)
        assert np.isfinite(float(aux))

    def test_capacity_drops_are_masked_not_garbage(self):
        import dataclasses
        spec, params = self._spec(cf=0.25)  # aggressive drops
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64))
        y, _ = moe_lib.moe_apply(spec, params, x)
        assert np.isfinite(np.asarray(y)).all()
        # dropped tokens shrink ‖y‖ vs the no-drop run, never explode it
        spec2, _ = self._spec(cf=64.0)
        y2, _ = moe_lib.moe_apply(spec2, params, x)
        assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y2)) + 1e-3

    def test_positions_in_expert(self):
        e = jnp.array([1, 0, 1, 1, 0, 2], jnp.int32)
        pos = moe_lib._positions_in_expert(e, 3)
        np.testing.assert_array_equal(np.asarray(pos), [0, 0, 1, 2, 1, 0])

    def test_grad_flows_through_dispatch(self):
        spec, params = self._spec()
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 64))

        def loss(p):
            y, aux = moe_lib.moe_apply(spec, p, x)
            return jnp.sum(y ** 2) + 0.01 * aux

        g = jax.grad(loss)(params)
        gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0


class TestSSD:
    def test_chunked_matches_naive_recurrence(self):
        from repro.models.ssd import ssd_chunked
        B, T, H, P, N = 1, 16, 2, 4, 8
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (B, T, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, T, 1, N))
        Cm = jax.random.normal(jax.random.PRNGKey(9), (B, T, 1, N))
        y, h_last = ssd_chunked(x, dt, A, Bm, Cm, chunk=4)
        # naive: h_t = exp(dt·A) h + dt·B⊗x ; y_t = C·h
        h = jnp.zeros((B, H, P, N))
        ys = []
        for t in range(T):
            a = jnp.exp(dt[:, t] * A)                      # (B, H)
            h = (a[:, :, None, None] * h
                 + jnp.einsum("bh,bn,bhp->bhpn", dt[:, t], Bm[:, t, 0], x[:, t]))
            ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t, 0], h))
        want = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                                   rtol=1e-4, atol=1e-4)


class TestRGLRU:
    def test_scan_matches_naive(self):
        from repro.models.rglru import _rglru_scan
        B, T, W = 2, 10, 6
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(ks[0], (B, T, W))
        r = jax.random.normal(ks[1], (B, T, W))
        i = jax.random.normal(ks[2], (B, T, W))
        lam = jnp.ones((W,))
        h_seq, h_last = _rglru_scan(x, r, i, lam, c=8.0)
        h = jnp.zeros((B, W))
        for t in range(T):
            log_a = -8.0 * jax.nn.softplus(lam) * jax.nn.sigmoid(r[:, t])
            a = jnp.exp(log_a)
            beta = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12))
            h = a * h + beta * (jax.nn.sigmoid(i[:, t]) * x[:, t])
        np.testing.assert_allclose(np.asarray(h_seq[:, -1]), np.asarray(h),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                                   rtol=1e-5, atol=1e-5)
