"""Tests for Algorithm 2 (BLAST factorization) — paper §3.2, Fig. 3/9, Thm 1."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blast
from repro.core.factorize import factorize, factorize_weight, normalized_error


def synth_low_rank(key, n, r_true):
    k1, k2 = jax.random.split(key)
    B = jax.random.normal(k1, (n, r_true))
    C = jax.random.normal(k2, (r_true, n))
    return (B @ C) / jnp.sqrt(r_true)


def synth_blast(key, n, b, r_true):
    params = blast.init(key, n, n, b, r_true)
    return blast.to_dense(params)


class TestTheorem1:
    def test_spectral_gd_monotone_nonincreasing(self):
        """Theorem 1: spectral step sizes ⇒ loss never increases."""
        A = synth_low_rank(jax.random.PRNGKey(0), 64, 4)
        res = factorize(A, b=4, r=8, steps=40, spectral_steps=True,
                        precondition=False, key=jax.random.PRNGKey(1))
        losses = np.asarray(res.losses)
        assert np.all(np.diff(losses) <= 1e-4 * losses[:-1] + 1e-6), losses


class TestPrecGD:
    def test_exact_rank_recovers_low_rank(self):
        """Fig 3-left: r = r* recovers the target with small error."""
        A = synth_low_rank(jax.random.PRNGKey(0), 256, 8)
        res = factorize(A, b=16, r=8, steps=120, key=jax.random.PRNGKey(1))
        err = float(normalized_error(A, res.params))
        assert err < 0.05, err

    def test_overparam_precgd_beats_gd(self):
        """Fig 3-right: r > r* — PrecGD reaches low error, plain GD stalls."""
        A = synth_low_rank(jax.random.PRNGKey(0), 256, 8)
        prec = factorize(A, b=16, r=32, steps=120, precondition=True,
                         key=jax.random.PRNGKey(1))
        gd = factorize(A, b=16, r=32, steps=120, precondition=False,
                       spectral_steps=True, key=jax.random.PRNGKey(1))
        e_prec = float(normalized_error(A, prec.params))
        e_gd = float(normalized_error(A, gd.params))
        assert e_prec < 0.1, (e_prec, e_gd)
        assert e_prec < e_gd, (e_prec, e_gd)

    def test_blast_target_recovered(self):
        """Fig 9: synthetic BLAST₁₆ target, exact parameterization."""
        A = synth_blast(jax.random.PRNGKey(3), 256, 16, 8)
        res = factorize(A, b=16, r=8, steps=150, key=jax.random.PRNGKey(4))
        err = float(normalized_error(A, res.params))
        assert err < 0.15, err

    def test_factorize_weight_roundtrip_dtype(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (48, 32), dtype=jnp.bfloat16)
        out = factorize_weight(w, b=4, r=32, steps=60)
        assert out["U"].dtype == jnp.bfloat16
        approx = blast.to_dense(
            blast.BlastParams(out["U"].astype(jnp.float32),
                              out["S"].astype(jnp.float32),
                              out["V"].astype(jnp.float32)))
        rel = float(jnp.linalg.norm(approx - w.T.astype(jnp.float32)) /
                    jnp.linalg.norm(w.astype(jnp.float32)))
        assert rel < 0.2, rel  # r=32=full for 32-dim side → near-exact up to bf16

    def test_loss_decreases_substantially(self):
        A = synth_low_rank(jax.random.PRNGKey(2), 128, 4)
        res = factorize(A, b=8, r=16, steps=80, key=jax.random.PRNGKey(5))
        losses = np.asarray(res.losses)
        assert res.final_loss < 1e-2 * losses[0]
